//! The rank fabric: threads + mailboxes + optional wire delays.
//!
//! Two launch modes share one `RankCtx` communicator:
//!
//! * [`Fabric::run`] — the one-shot SPMD launcher: spawn `nprocs` scoped
//!   rank threads, run one closure, join. The pool's spin-up (plus any
//!   worker pools the closure creates) is paid on EVERY call.
//! * [`ResidentFabric`] — the serving-mode pool: rank threads outlive a
//!   single closure and loop on a per-rank job mailbox, so repeated
//!   rounds ([`ResidentFabric::run`] / [`ResidentFabric::run_report`])
//!   reuse the same threads, mailboxes and metrics. This is what
//!   [`TransformServer`](crate::server::TransformServer) executes
//!   coalesced transform rounds on.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::layout::Rank;
use crate::obs::{EventKind, Trace, Tracer};

use super::topology::Topology;

/// One message in flight. `tag` disambiguates concurrent exchanges
/// (collectives use tags below [`super::USER_TAG_BASE`]).
#[derive(Debug)]
pub struct Envelope {
    pub src: Rank,
    pub tag: u64,
    pub bytes: Vec<u8>,
}

/// Wire-delay model: when enabled, each message is delivered by the
/// sender's injector ("NIC") thread after `latency + bytes·per_byte`
/// seconds, serialised per source — a non-blocking `Isend` whose payload
/// arrives later, so communication–computation overlap is measurable in
/// real time (ablation_overlap bench).
#[derive(Clone, Debug)]
pub struct WireModel {
    pub topology: Topology,
    /// Scale factor: modeled seconds → real sleep seconds.
    pub time_scale: f64,
}

/// Fabric-wide counters (atomics: written by all rank threads).
#[derive(Debug, Default)]
pub struct FabricMetrics {
    pub messages: AtomicU64,
    pub remote_messages: AtomicU64,
    pub bytes: AtomicU64,
    pub remote_bytes: AtomicU64,
    /// Wire-buffer arena hits: packs that started from a recycled
    /// received-envelope buffer ([`RankCtx::take_wire_buf`]) instead of
    /// a fresh allocation.
    pub arena_reuse_hits: AtomicU64,
    /// Capacity (bytes) of the recycled buffers — heap traffic avoided.
    pub alloc_bytes_saved: AtomicU64,
}

impl FabricMetrics {
    fn record(&self, src: Rank, dst: Rank, len: usize) {
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(len as u64, Ordering::Relaxed);
        if src != dst {
            self.remote_messages.fetch_add(1, Ordering::Relaxed);
            self.remote_bytes.fetch_add(len as u64, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> FabricReport {
        FabricReport {
            messages: self.messages.load(Ordering::Relaxed),
            remote_messages: self.remote_messages.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            remote_bytes: self.remote_bytes.load(Ordering::Relaxed),
            arena_reuse_hits: self.arena_reuse_hits.load(Ordering::Relaxed),
            alloc_bytes_saved: self.alloc_bytes_saved.load(Ordering::Relaxed),
        }
    }
}

/// Immutable summary of a fabric run (or, in resident mode, of one
/// round — see [`FabricReport::since`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FabricReport {
    pub messages: u64,
    pub remote_messages: u64,
    pub bytes: u64,
    pub remote_bytes: u64,
    /// Packs served from the per-rank wire-buffer arena (steady-state
    /// resident rounds: every remote pack). Cold rounds report 0.
    pub arena_reuse_hits: u64,
    /// Capacity of the recycled buffers (bytes); allocator-dependent —
    /// a gauge, not an exact count.
    pub alloc_bytes_saved: u64,
}

impl FabricReport {
    /// Counter deltas relative to an earlier snapshot (saturating). A
    /// resident fabric's metrics are cumulative over the pool's whole
    /// life; [`ResidentFabric::run_report`] snapshots before and after
    /// each round and returns `after.since(&before)`, so per-round
    /// traffic is collectable without tearing the pool down.
    pub fn since(&self, baseline: &FabricReport) -> FabricReport {
        FabricReport {
            messages: self.messages.saturating_sub(baseline.messages),
            remote_messages: self.remote_messages.saturating_sub(baseline.remote_messages),
            bytes: self.bytes.saturating_sub(baseline.bytes),
            remote_bytes: self.remote_bytes.saturating_sub(baseline.remote_bytes),
            arena_reuse_hits: self.arena_reuse_hits.saturating_sub(baseline.arena_reuse_hits),
            alloc_bytes_saved: self.alloc_bytes_saved.saturating_sub(baseline.alloc_bytes_saved),
        }
    }

    /// Fold another report's counters into this one (e.g. summing
    /// per-round reports into a serving-lifetime total).
    pub fn accumulate(&mut self, other: &FabricReport) {
        self.messages += other.messages;
        self.remote_messages += other.remote_messages;
        self.bytes += other.bytes;
        self.remote_bytes += other.remote_bytes;
        self.arena_reuse_hits += other.arena_reuse_hits;
        self.alloc_bytes_saved += other.alloc_bytes_saved;
    }
}

enum Outbound {
    Msg { dst: Rank, env: Envelope },
    Stop,
}

/// Per-rank fault knobs, all atomics so the injector can be reconfigured
/// from a test driver while rank threads are mid-round.
#[derive(Debug, Default)]
struct RankFaults {
    /// Sleep this many nanoseconds before EVERY send from this rank
    /// (0 = off) — a uniformly slow rank, the heterogeneous-network
    /// scenario.
    delay_nanos: AtomicU64,
    /// Swallow this many upcoming sends from this rank — the peer never
    /// receives them (a wedged rank; receivers only recover via a
    /// deadline, e.g. [`RankCtx::recv_any_deadline`]).
    drop_next: AtomicU64,
    /// Truncate the payload of this many upcoming sends from this rank
    /// by one byte (one byte is appended when the payload is empty), so
    /// the receiver's length validation fails and names the sender — a
    /// rogue rank emitting malformed traffic.
    corrupt_next: AtomicU64,
}

/// Decrement `counter` by one if positive; `true` when a unit was taken.
fn take_one(counter: &AtomicU64) -> bool {
    let mut cur = counter.load(Ordering::Relaxed);
    while cur > 0 {
        match counter.compare_exchange_weak(cur, cur - 1, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return true,
            Err(now) => cur = now,
        }
    }
    false
}

/// Compiled-in, default-off fault injection for a fabric's sends: per
/// source rank, delay every send, swallow the next N sends, or corrupt
/// the next N payloads. Attach one to a pool with
/// [`ResidentFabric::with_faults`] (or to a server via
/// [`ServerConfig::faults`](crate::server::ServerConfig)); with no
/// injector attached — the default everywhere — the send path does not
/// change at all. Counters record how many faults actually fired, so
/// chaos tests can assert their fault landed in a round.
///
/// Dropped sends are counted by [`FabricMetrics`] as sent (the fault
/// models a message lost *after* posting); corrupted sends are counted
/// with their corrupted length.
#[derive(Debug)]
pub struct FaultInjector {
    ranks: Vec<RankFaults>,
    delays_injected: AtomicU64,
    drops_injected: AtomicU64,
    corruptions_injected: AtomicU64,
}

impl FaultInjector {
    /// A no-fault injector for a pool of `nprocs` ranks.
    pub fn new(nprocs: usize) -> FaultInjector {
        FaultInjector {
            ranks: (0..nprocs).map(|_| RankFaults::default()).collect(),
            delays_injected: AtomicU64::new(0),
            drops_injected: AtomicU64::new(0),
            corruptions_injected: AtomicU64::new(0),
        }
    }

    pub fn nprocs(&self) -> usize {
        self.ranks.len()
    }

    /// Delay every send from `rank` by `delay` until cleared (a slow
    /// rank). `Duration::ZERO` turns the delay off.
    pub fn delay_sends(&self, rank: Rank, delay: Duration) {
        self.ranks[rank]
            .delay_nanos
            .store(delay.as_nanos().min(u64::MAX as u128) as u64, Ordering::Relaxed);
    }

    /// Swallow the next `count` sends from `rank` (a wedged rank).
    pub fn drop_next_sends(&self, rank: Rank, count: u64) {
        self.ranks[rank].drop_next.store(count, Ordering::Relaxed);
    }

    /// Corrupt the payload of the next `count` sends from `rank` (a
    /// rogue rank): the receiver's length validation fails, naming
    /// `rank` as the sender.
    pub fn corrupt_next_sends(&self, rank: Rank, count: u64) {
        self.ranks[rank].corrupt_next.store(count, Ordering::Relaxed);
    }

    /// Turn every configured fault off (fired-fault counters are kept).
    pub fn clear(&self) {
        for f in &self.ranks {
            f.delay_nanos.store(0, Ordering::Relaxed);
            f.drop_next.store(0, Ordering::Relaxed);
            f.corrupt_next.store(0, Ordering::Relaxed);
        }
    }

    /// How many sends were delayed so far.
    pub fn delays_injected(&self) -> u64 {
        self.delays_injected.load(Ordering::Relaxed)
    }

    /// How many sends were swallowed so far.
    pub fn drops_injected(&self) -> u64 {
        self.drops_injected.load(Ordering::Relaxed)
    }

    /// How many payloads were corrupted so far.
    pub fn corruptions_injected(&self) -> u64 {
        self.corruptions_injected.load(Ordering::Relaxed)
    }

    /// Apply the configured faults to one outgoing payload from `src`,
    /// reporting exactly which faults fired so the send path can both
    /// honour the outcome and trace it.
    fn apply(&self, src: Rank, bytes: &mut Vec<u8>) -> FaultOutcome {
        let mut fired = FaultOutcome::default();
        let f = &self.ranks[src];
        let nanos = f.delay_nanos.load(Ordering::Relaxed);
        if nanos > 0 {
            self.delays_injected.fetch_add(1, Ordering::Relaxed);
            fired.delayed = true;
            std::thread::sleep(Duration::from_nanos(nanos));
        }
        if take_one(&f.drop_next) {
            self.drops_injected.fetch_add(1, Ordering::Relaxed);
            fired.dropped = true;
            return fired;
        }
        if take_one(&f.corrupt_next) {
            self.corruptions_injected.fetch_add(1, Ordering::Relaxed);
            fired.corrupted = true;
            match bytes.pop() {
                Some(_) => {}
                None => bytes.push(0xC0),
            }
        }
        fired
    }
}

/// Which faults [`FaultInjector::apply`] fired on one send. `dropped`
/// means the send was swallowed entirely.
#[derive(Clone, Copy, Debug, Default)]
struct FaultOutcome {
    delayed: bool,
    dropped: bool,
    corrupted: bool,
}

/// Resident rank threads currently alive process-wide (every
/// [`ResidentFabric`]'s threads, across all pools). Dropping a pool
/// joins its threads, so after the last pool is gone this returns 0 —
/// the leak check `tests/server_soak.rs` (and CI) pins.
pub fn live_rank_threads() -> usize {
    LIVE_RANK_THREADS.load(Ordering::SeqCst)
}

static LIVE_RANK_THREADS: AtomicUsize = AtomicUsize::new(0);

/// RAII increment of [`LIVE_RANK_THREADS`] for one resident rank
/// thread's lifetime; the Drop runs even if the thread's job loop
/// unwinds, so the counter can never over-report after a join.
struct LiveThreadGuard;

impl LiveThreadGuard {
    fn new() -> LiveThreadGuard {
        LIVE_RANK_THREADS.fetch_add(1, Ordering::SeqCst);
        LiveThreadGuard
    }
}

impl Drop for LiveThreadGuard {
    fn drop(&mut self) {
        LIVE_RANK_THREADS.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Per-rank handle: the MPI communicator analogue.
pub struct RankCtx {
    rank: Rank,
    nprocs: usize,
    mailboxes: Vec<Sender<Envelope>>,
    injector: Option<Sender<Outbound>>,
    rx: Receiver<Envelope>,
    pending: VecDeque<Envelope>,
    metrics: Arc<FabricMetrics>,
    faults: Option<Arc<FaultInjector>>,
    tracer: Option<Tracer>,
    pub(super) collective_gen: u64,
    user_gen: u64,
    /// Per-rank wire-buffer arena: spent receive buffers recycled into
    /// the next round's packs ([`Self::take_wire_buf`] /
    /// [`Self::recycle_wire_buf`]). Rank-private, so no locking.
    wire_pool: Vec<Vec<u8>>,
}

impl RankCtx {
    pub fn rank(&self) -> Rank {
        self.rank
    }

    pub fn nprocs(&self) -> usize {
        self.nprocs
    }

    pub fn metrics(&self) -> &FabricMetrics {
        &self.metrics
    }

    /// This rank's trace recorder, when the fabric was launched traced
    /// ([`Fabric::run_report_traced`] /
    /// [`ResidentFabric::with_faults_traced`]). `None` — the default —
    /// costs one branch on the paths that consult it.
    pub fn tracer(&self) -> Option<&Tracer> {
        self.tracer.as_ref()
    }

    /// Take a wire buffer from this rank's arena — empty, but with the
    /// retained capacity of a previously received envelope — or a fresh
    /// `Vec` when the arena is dry. Reuse is counted in
    /// [`FabricMetrics::arena_reuse_hits`] / `alloc_bytes_saved`; on a
    /// steady-state resident fabric every remote pack is a hit, making
    /// the round allocation-free on the wire path.
    pub fn take_wire_buf(&mut self) -> Vec<u8> {
        match self.wire_pool.pop() {
            Some(buf) => {
                debug_assert!(buf.is_empty());
                self.metrics.arena_reuse_hits.fetch_add(1, Ordering::Relaxed);
                self.metrics
                    .alloc_bytes_saved
                    .fetch_add(buf.capacity() as u64, Ordering::Relaxed);
                buf
            }
            None => Vec::new(),
        }
    }

    /// Return a spent wire buffer (typically a consumed envelope's
    /// payload) to the arena for a later pack. Zero-capacity buffers are
    /// not worth keeping, and the pool is capped at the rank count — a
    /// rank receives at most `nprocs - 1` packages per round, so the cap
    /// bounds arena memory at one round's worth of buffers.
    pub fn recycle_wire_buf(&mut self, mut buf: Vec<u8>) {
        if buf.capacity() == 0 || self.wire_pool.len() >= self.nprocs {
            return;
        }
        buf.clear();
        self.wire_pool.push(buf);
    }

    /// Fresh tag for one engine-level exchange. SPMD contract: every rank
    /// calls this in the same order, so tags agree across ranks and
    /// back-to-back exchanges can never interleave.
    pub fn next_user_tag(&mut self) -> u64 {
        self.user_gen += 1;
        super::USER_TAG_BASE + self.user_gen
    }

    /// Non-blocking send (MPI_Isend analogue): enqueues and returns. The
    /// payload is moved, not copied. With a [`FaultInjector`] attached
    /// the send may first be delayed, corrupted, or swallowed entirely.
    pub fn send(&self, dst: Rank, tag: u64, mut bytes: Vec<u8>) {
        if let Some(faults) = &self.faults {
            let fired = faults.apply(self.rank, &mut bytes);
            if let Some(t) = &self.tracer {
                if fired.delayed {
                    t.instant_io(EventKind::FaultDelay, dst as i64, bytes.len() as u64);
                }
                if fired.corrupted {
                    t.instant_io(EventKind::FaultCorrupt, dst as i64, bytes.len() as u64);
                }
                if fired.dropped {
                    t.instant_io(EventKind::FaultDrop, dst as i64, bytes.len() as u64);
                }
            }
            if fired.dropped {
                // swallowed: the fault models a message lost after
                // posting, so it still counts as sent
                self.metrics.record(self.rank, dst, bytes.len());
                return;
            }
        }
        if let Some(t) = &self.tracer {
            t.instant_io(EventKind::Send, dst as i64, bytes.len() as u64);
        }
        self.metrics.record(self.rank, dst, bytes.len());
        let env = Envelope {
            src: self.rank,
            tag,
            bytes,
        };
        match (&self.injector, dst == self.rank) {
            // local sends bypass the wire even under a wire model
            (Some(inj), false) => inj
                .send(Outbound::Msg { dst, env })
                .expect("injector thread died"),
            _ => self.mailboxes[dst].send(env).expect("destination rank died"),
        }
    }

    /// Blocking receive of the next message with tag `tag`, from anyone
    /// (MPI_Waitany analogue). Other tags are buffered, not lost.
    pub fn recv_any(&mut self, tag: u64) -> Envelope {
        if let Some(pos) = self.pending.iter().position(|e| e.tag == tag) {
            return self.pending.remove(pos).unwrap();
        }
        loop {
            let env = self.rx.recv().expect("fabric closed while receiving");
            if env.tag == tag {
                return env;
            }
            self.pending.push_back(env);
        }
    }

    /// Like [`Self::recv_any`], but gives up at `deadline`: `None` means
    /// the deadline passed with no matching envelope (other tags keep
    /// being buffered, not lost). Already-delivered envelopes are still
    /// drained when the deadline has ALREADY passed — the channel is
    /// polled once before any timeout verdict — so a receiver that was
    /// merely busy consumes everything that arrived in the meantime and
    /// only genuinely missing traffic times out. The schedule engine's
    /// exchange deadline
    /// ([`crate::engine::EngineConfig::exchange_timeout`]) is built on
    /// this.
    pub fn recv_any_deadline(&mut self, tag: u64, deadline: Instant) -> Option<Envelope> {
        if let Some(pos) = self.pending.iter().position(|e| e.tag == tag) {
            return self.pending.remove(pos);
        }
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            match self.rx.recv_timeout(remaining) {
                Ok(env) if env.tag == tag => return Some(env),
                Ok(env) => self.pending.push_back(env),
                Err(RecvTimeoutError::Timeout) => {
                    if let Some(t) = &self.tracer {
                        t.instant(EventKind::Timeout);
                    }
                    return None;
                }
                Err(RecvTimeoutError::Disconnected) => {
                    panic!("fabric closed while receiving")
                }
            }
        }
    }

    /// Non-blocking receive (MPI_Iprobe + MPI_Recv analogue): the next
    /// already-delivered message with tag `tag`, from anyone, or `None`
    /// when nothing with that tag has arrived yet. Other tags are
    /// buffered, not lost. The pipelined executor drains arrivals with
    /// this between posting sends, so early packages are unpacked while
    /// later packages are still being packed.
    pub fn try_recv(&mut self, tag: u64) -> Option<Envelope> {
        if let Some(pos) = self.pending.iter().position(|e| e.tag == tag) {
            return self.pending.remove(pos);
        }
        loop {
            match self.rx.try_recv() {
                Ok(env) if env.tag == tag => return Some(env),
                Ok(env) => self.pending.push_back(env),
                Err(_) => return None,
            }
        }
    }

    /// Discard every buffered envelope whose user tag has already been
    /// drawn (tag ≤ the current [`Self::next_user_tag`] watermark).
    ///
    /// Resident-mode drivers call this between rounds: a round that
    /// errored out early (deferred pack error, malformed package) may
    /// leave already-delivered packages unconsumed, and in a one-shot
    /// fabric the rank thread dies with them — but a resident rank
    /// thread lives on, and stale envelopes would otherwise accumulate
    /// in the pending buffer forever (tag-scoped, so harmless for
    /// correctness, but a leak and a per-receive scan cost). Collective
    /// tags and tags not yet drawn are kept.
    pub fn flush_user_backlog(&mut self) {
        while let Ok(env) = self.rx.try_recv() {
            self.pending.push_back(env);
        }
        let watermark = super::USER_TAG_BASE + self.user_gen;
        self.pending.retain(|e| e.tag < super::USER_TAG_BASE || e.tag > watermark);
    }

    /// Blocking receive from a specific source and tag.
    pub fn recv_from(&mut self, src: Rank, tag: u64) -> Envelope {
        if let Some(pos) = self
            .pending
            .iter()
            .position(|e| e.tag == tag && e.src == src)
        {
            return self.pending.remove(pos).unwrap();
        }
        loop {
            let env = self.rx.recv().expect("fabric closed while receiving");
            if env.tag == tag && env.src == src {
                return env;
            }
            self.pending.push_back(env);
        }
    }
}

/// A forced per-receiver delivery order for ONE user-tagged exchange,
/// driven by [`Fabric::run_scripted`]. The delivery-order model checker
/// ([`crate::analysis::check_transform`]) enumerates these.
///
/// `order[dst]` lists the source ranks whose user-tagged envelopes are
/// released to `dst`'s mailbox in exactly that order (each pair at most
/// once — the schedule scripts a single exchange). `drops` lists
/// `(src, dst)` pairs whose user-tagged messages are swallowed entirely,
/// for deadlock-class negative tests: the receiver can only recover via
/// [`crate::engine::EngineConfig::exchange_timeout`], whose error names
/// the missing sender.
///
/// Collective traffic (tags below [`super::USER_TAG_BASE`]) is never
/// scripted: it is forwarded immediately, so barriers and gathers cannot
/// wedge the router.
#[derive(Clone, Debug, Default)]
pub struct DeliverySchedule {
    pub order: Vec<Vec<Rank>>,
    pub drops: Vec<(Rank, Rank)>,
}

impl DeliverySchedule {
    /// A schedule forcing the given per-receiver arrival orders, with no
    /// drops.
    pub fn new(order: Vec<Vec<Rank>>) -> DeliverySchedule {
        DeliverySchedule {
            order,
            drops: Vec::new(),
        }
    }

    /// Swallow all user-tagged messages from `src` to `dst`.
    pub fn dropping(mut self, src: Rank, dst: Rank) -> DeliverySchedule {
        self.drops.push((src, dst));
        self
    }

    fn validate(&self, nprocs: usize) {
        assert_eq!(self.order.len(), nprocs, "schedule must cover every receiver");
        for (dst, srcs) in self.order.iter().enumerate() {
            let mut seen = vec![false; nprocs];
            for &src in srcs {
                assert!(src < nprocs, "schedule names rank {src} outside 0..{nprocs}");
                assert_ne!(src, dst, "local sends bypass the wire and cannot be scripted");
                assert!(!seen[src], "schedule lists sender {src} twice for receiver {dst}");
                seen[src] = true;
            }
        }
    }
}

/// What the scripted router actually observed in one
/// [`Fabric::run_scripted`] run. All pairs are `(src, dst)`.
#[derive(Clone, Debug, Default)]
pub struct DeliveryLog {
    /// User-tagged envelopes released in the forced order.
    pub delivered: Vec<(Rank, Rank)>,
    /// User-tagged envelopes from pairs the schedule did not script
    /// (forwarded immediately, but flagged — the model checker treats
    /// any unexpected pair as a violation).
    pub unexpected: Vec<(Rank, Rank)>,
    /// Scheduled pairs whose envelope never arrived by shutdown: an
    /// eligible sender that never sent — the structural deadlock class.
    pub undelivered: Vec<(Rank, Rank)>,
    /// Pairs swallowed per [`DeliverySchedule::drops`].
    pub dropped: Vec<(Rank, Rank)>,
}

impl DeliveryLog {
    /// Every scheduled envelope arrived and was released, nothing
    /// unscripted showed up.
    pub fn is_clean(&self) -> bool {
        self.unexpected.is_empty() && self.undelivered.is_empty()
    }
}

/// The fabric launcher.
pub struct Fabric;

impl Fabric {
    /// Run `f` on `nprocs` rank threads; returns per-rank results in rank
    /// order. Panics in any rank propagate.
    pub fn run<R: Send>(
        nprocs: usize,
        wire: Option<WireModel>,
        f: impl Fn(&mut RankCtx) -> R + Send + Sync,
    ) -> Vec<R> {
        Self::run_report(nprocs, wire, f).0
    }

    /// Like [`Fabric::run`], also returning the traffic report.
    pub fn run_report<R: Send>(
        nprocs: usize,
        wire: Option<WireModel>,
        f: impl Fn(&mut RankCtx) -> R + Send + Sync,
    ) -> (Vec<R>, FabricReport) {
        Self::run_report_traced(nprocs, wire, None, f)
    }

    /// Like [`Fabric::run_report`], with each rank recording into a
    /// `rank R` track of `trace` (`None` is exactly
    /// [`Fabric::run_report`]). This is what `--trace-out` on the CLI
    /// subcommands and `costa trace` run on.
    pub fn run_report_traced<R: Send>(
        nprocs: usize,
        wire: Option<WireModel>,
        trace: Option<&Arc<Trace>>,
        f: impl Fn(&mut RankCtx) -> R + Send + Sync,
    ) -> (Vec<R>, FabricReport) {
        assert!(nprocs > 0);
        let metrics = Arc::new(FabricMetrics::default());
        let mut mailboxes = Vec::with_capacity(nprocs);
        let mut rxs = Vec::with_capacity(nprocs);
        for _ in 0..nprocs {
            let (tx, rx) = channel::<Envelope>();
            mailboxes.push(tx);
            rxs.push(rx);
        }

        let (injectors, injector_threads) = spawn_injectors(&wire, nprocs, &mailboxes);

        let results: Vec<R> = std::thread::scope(|scope| {
            let handles: Vec<_> = rxs
                .into_iter()
                .enumerate()
                .map(|(rank, rx)| {
                    let mut ctx = RankCtx {
                        rank,
                        nprocs,
                        mailboxes: mailboxes.clone(),
                        injector: injectors[rank].clone(),
                        rx,
                        pending: VecDeque::new(),
                        metrics: metrics.clone(),
                        faults: None,
                        tracer: trace.map(|tr| tr.tracer(&format!("rank {rank}"))),
                        collective_gen: 0,
                        user_gen: 0,
                        wire_pool: Vec::new(),
                    };
                    let f = &f;
                    scope.spawn(move || f(&mut ctx))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    // re-raise the ORIGINAL panic payload so callers (and
                    // should_panic tests) see the real failure message
                    h.join()
                        .unwrap_or_else(|e| std::panic::resume_unwind(e))
                })
                .collect()
        });

        for inj in injectors.iter().flatten() {
            let _ = inj.send(Outbound::Stop);
        }
        drop(injectors);
        for t in injector_threads {
            let _ = t.join();
        }
        let report = metrics.snapshot();
        (results, report)
    }

    /// Like [`Fabric::run`], but every remote *user-tagged* send is
    /// routed through a deterministic delivery router that releases
    /// envelopes to each receiver in the order `schedule` dictates —
    /// regardless of the real interleaving of sender threads. This is
    /// the substrate of the delivery-order model checker
    /// ([`crate::analysis::check_transform`]): one closure, every
    /// possible per-receiver arrival order.
    ///
    /// Mechanics: each rank's send path is given the router as its
    /// injector, exactly like a [`WireModel`] NIC. The router holds a
    /// user-tagged envelope until its source is the next one scheduled
    /// for that destination, then releases it (and any now-unblocked
    /// successors) to the destination's real mailbox. Collective tags
    /// pass through immediately; local sends never reach the router
    /// (they bypass injectors entirely, as in production). Scheduled
    /// pairs that never materialise are recorded as `undelivered`;
    /// unscripted pairs are forwarded but recorded as `unexpected`.
    ///
    /// The schedule scripts ONE exchange: at most one user-tagged
    /// envelope per (src, dst) pair. Closures that run several
    /// exchanges need one `run_scripted` call per exchange.
    pub fn run_scripted<R: Send>(
        nprocs: usize,
        schedule: DeliverySchedule,
        f: impl Fn(&mut RankCtx) -> R + Send + Sync,
    ) -> (Vec<R>, DeliveryLog) {
        assert!(nprocs > 0);
        schedule.validate(nprocs);
        let metrics = Arc::new(FabricMetrics::default());
        let mut mailboxes = Vec::with_capacity(nprocs);
        let mut rxs = Vec::with_capacity(nprocs);
        for _ in 0..nprocs {
            let (tx, rx) = channel::<Envelope>();
            mailboxes.push(tx);
            rxs.push(rx);
        }

        // one router thread; every rank's injector slot is a clone of
        // the same intake sender
        let (intake, routed) = channel::<Outbound>();
        let boxes = mailboxes.clone();
        let router = std::thread::spawn(move || {
            let mut remaining: Vec<VecDeque<Rank>> = schedule
                .order
                .iter()
                .map(|srcs| srcs.iter().copied().collect())
                .collect();
            let mut held: Vec<Vec<VecDeque<Envelope>>> =
                (0..nprocs).map(|_| (0..nprocs).map(|_| VecDeque::new()).collect()).collect();
            let mut log = DeliveryLog::default();
            while let Ok(Outbound::Msg { dst, env }) = routed.recv() {
                if env.tag < super::USER_TAG_BASE {
                    // collectives are never scripted
                    let _ = boxes[dst].send(env);
                    continue;
                }
                let src = env.src;
                if schedule.drops.contains(&(src, dst)) {
                    log.dropped.push((src, dst));
                    continue;
                }
                if remaining[dst].contains(&src) {
                    held[dst][src].push_back(env);
                    // release the longest now-satisfiable prefix
                    while let Some(&next) = remaining[dst].front() {
                        match held[dst][next].pop_front() {
                            Some(e) => {
                                log.delivered.push((next, dst));
                                let _ = boxes[dst].send(e);
                                remaining[dst].pop_front();
                            }
                            None => break,
                        }
                    }
                } else {
                    // unscripted pair (or a second envelope on a
                    // scripted pair): forward, but flag it
                    log.unexpected.push((src, dst));
                    let _ = boxes[dst].send(env);
                }
            }
            for (dst, rem) in remaining.iter().enumerate() {
                for &src in rem {
                    log.undelivered.push((src, dst));
                }
            }
            log
        });

        let results: Vec<R> = std::thread::scope(|scope| {
            let handles: Vec<_> = rxs
                .into_iter()
                .enumerate()
                .map(|(rank, rx)| {
                    let mut ctx = RankCtx {
                        rank,
                        nprocs,
                        mailboxes: mailboxes.clone(),
                        injector: Some(intake.clone()),
                        rx,
                        pending: VecDeque::new(),
                        metrics: metrics.clone(),
                        faults: None,
                        tracer: None,
                        collective_gen: 0,
                        user_gen: 0,
                        wire_pool: Vec::new(),
                    };
                    let f = &f;
                    scope.spawn(move || f(&mut ctx))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .unwrap_or_else(|e| std::panic::resume_unwind(e))
                })
                .collect()
        });

        let _ = intake.send(Outbound::Stop);
        drop(intake);
        let log = router.join().expect("scripted router panicked");
        (results, log)
    }
}

/// Injector ("NIC") threads, one per source rank, FIFO per source.
/// Shared by the one-shot launcher and the resident pool.
fn spawn_injectors(
    wire: &Option<WireModel>,
    nprocs: usize,
    mailboxes: &[Sender<Envelope>],
) -> (Vec<Option<Sender<Outbound>>>, Vec<std::thread::JoinHandle<()>>) {
    let mut injectors: Vec<Option<Sender<Outbound>>> = vec![None; nprocs];
    let mut injector_threads = Vec::new();
    if let Some(w) = wire {
        for src in 0..nprocs {
            let (tx, rx) = channel::<Outbound>();
            injectors[src] = Some(tx);
            let boxes = mailboxes.to_vec();
            let topo = w.topology.clone();
            let scale = w.time_scale;
            injector_threads.push(std::thread::spawn(move || {
                while let Ok(Outbound::Msg { dst, env }) = rx.recv() {
                    let secs = topo.link_cost(src, dst, env.bytes.len() as u64) * scale;
                    if secs > 0.0 {
                        std::thread::sleep(Duration::from_secs_f64(secs));
                    }
                    if boxes[dst].send(env).is_err() {
                        break; // receiver done — drop late traffic
                    }
                }
            }));
        }
    }
    (injectors, injector_threads)
}

/// One unit of work for a resident rank thread.
enum RankJob {
    Run(Box<dyn FnOnce(&mut RankCtx) + Send>),
    Stop,
}

/// A persistent rank pool: `nprocs` rank threads that outlive a single
/// closure, each looping on a per-rank job mailbox. Spin-up (threads,
/// mailboxes, injectors) is paid ONCE per pool, not once per round —
/// the serving-mode counterpart of [`Fabric::run`], and what
/// [`TransformServer`](crate::server::TransformServer) executes its
/// coalesced rounds on.
///
/// Each [`Self::run`]/[`Self::run_report`] call is one SPMD *round*: the
/// closure runs once on every rank, results come back in rank order, and
/// `run_report` additionally returns the round's own [`FabricReport`]
/// delta (per-round snapshots via [`FabricReport::since`], not
/// end-of-life totals). Rounds are serialized internally — concurrent
/// callers queue — because the SPMD tag contract requires every rank to
/// observe rounds in the same order.
///
/// A panic inside a round is caught on the rank thread (the pool
/// survives) and re-raised to the `run` caller once every rank has
/// reported. The engine's execution paths are panic-free by contract
/// (malformed traffic is an `Err` naming the sender), so a panic here is
/// a caller bug; note that a rank that panics *mid-exchange* may leave
/// peers blocked on receives, so drivers should treat a panicked round
/// as poisoning the pool.
///
/// ```
/// use costa::net::ResidentFabric;
///
/// let pool = ResidentFabric::new(2, None);
/// for round in 0..3u8 {
///     let (echoes, report) = pool.run_report(move |ctx| {
///         let peer = 1 - ctx.rank();
///         let tag = ctx.next_user_tag();
///         ctx.send(peer, tag, vec![round]);
///         ctx.recv_any(tag).bytes[0]
///     });
///     assert_eq!(echoes, vec![round, round]);
///     assert_eq!(report.messages, 2, "per-round delta, not cumulative");
/// }
/// assert_eq!(pool.report().messages, 6, "cumulative over the pool's life");
/// ```
pub struct ResidentFabric {
    nprocs: usize,
    jobs: Vec<Sender<RankJob>>,
    rank_threads: Vec<std::thread::JoinHandle<()>>,
    injectors: Vec<Option<Sender<Outbound>>>,
    injector_threads: Vec<std::thread::JoinHandle<()>>,
    metrics: Arc<FabricMetrics>,
    round_lock: Mutex<()>,
}

impl ResidentFabric {
    /// Spawn the pool: `nprocs` resident rank threads (plus injector
    /// threads when a wire model is given), idle until the first round.
    pub fn new(nprocs: usize, wire: Option<WireModel>) -> ResidentFabric {
        Self::with_faults(nprocs, wire, None)
    }

    /// Like [`Self::new`], with an optional [`FaultInjector`] attached
    /// to every rank's send path (chaos testing; `None` — the production
    /// configuration — changes nothing).
    pub fn with_faults(
        nprocs: usize,
        wire: Option<WireModel>,
        faults: Option<Arc<FaultInjector>>,
    ) -> ResidentFabric {
        Self::with_faults_traced(nprocs, wire, faults, None)
    }

    /// Like [`Self::with_faults`], with each resident rank thread
    /// recording into a `rank R` track of `trace` for the pool's whole
    /// life. This is the pool's *flight recorder*: the track rings keep
    /// the last events per rank across rounds, so when a round fails
    /// the server can snapshot them into the error path
    /// ([`Trace::flight_summary`]). `None` is exactly
    /// [`Self::with_faults`].
    pub fn with_faults_traced(
        nprocs: usize,
        wire: Option<WireModel>,
        faults: Option<Arc<FaultInjector>>,
        trace: Option<Arc<Trace>>,
    ) -> ResidentFabric {
        assert!(nprocs > 0);
        if let Some(f) = &faults {
            assert_eq!(f.nprocs(), nprocs, "fault injector sized for a different pool");
        }
        let metrics = Arc::new(FabricMetrics::default());
        let mut mailboxes = Vec::with_capacity(nprocs);
        let mut rxs = Vec::with_capacity(nprocs);
        for _ in 0..nprocs {
            let (tx, rx) = channel::<Envelope>();
            mailboxes.push(tx);
            rxs.push(rx);
        }
        let (injectors, injector_threads) = spawn_injectors(&wire, nprocs, &mailboxes);
        let mut jobs = Vec::with_capacity(nprocs);
        let mut rank_threads = Vec::with_capacity(nprocs);
        for (rank, rx) in rxs.into_iter().enumerate() {
            let (jtx, jrx) = channel::<RankJob>();
            jobs.push(jtx);
            let mut ctx = RankCtx {
                rank,
                nprocs,
                mailboxes: mailboxes.clone(),
                injector: injectors[rank].clone(),
                rx,
                pending: VecDeque::new(),
                metrics: metrics.clone(),
                faults: faults.clone(),
                tracer: trace.as_ref().map(|tr| tr.tracer(&format!("rank {rank}"))),
                collective_gen: 0,
                user_gen: 0,
                wire_pool: Vec::new(),
            };
            rank_threads.push(
                std::thread::Builder::new()
                    .name(format!("costa-rank-{rank}"))
                    .spawn(move || {
                        let _live = LiveThreadGuard::new();
                        while let Ok(job) = jrx.recv() {
                            match job {
                                RankJob::Run(run) => run(&mut ctx),
                                RankJob::Stop => break,
                            }
                        }
                    })
                    .expect("failed to spawn resident rank thread"),
            );
        }
        ResidentFabric {
            nprocs,
            jobs,
            rank_threads,
            injectors,
            injector_threads,
            metrics,
            round_lock: Mutex::new(()),
        }
    }

    pub fn nprocs(&self) -> usize {
        self.nprocs
    }

    /// Run one round of `f` on every resident rank; per-rank results in
    /// rank order. Panics in any rank propagate (after every rank has
    /// reported); the pool itself survives.
    pub fn run<R: Send + 'static>(
        &self,
        f: impl Fn(&mut RankCtx) -> R + Send + Sync + 'static,
    ) -> Vec<R> {
        self.run_report(f).0
    }

    /// Like [`Self::run`], also returning THIS round's traffic report —
    /// the delta between the pool's cumulative counters after and before
    /// the round ([`FabricReport::since`]).
    pub fn run_report<R: Send + 'static>(
        &self,
        f: impl Fn(&mut RankCtx) -> R + Send + Sync + 'static,
    ) -> (Vec<R>, FabricReport) {
        // a previous round's panic unwound through this guard; the lock
        // only serializes rounds (all ranks had reported by the time it
        // unwound), so poisoning is benign — recover the guard
        let _round = self.round_lock.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        let before = self.metrics.snapshot();
        let f = Arc::new(f);
        let (tx, rx) = channel::<(Rank, std::thread::Result<R>)>();
        for rank in 0..self.nprocs {
            let f = f.clone();
            let tx = tx.clone();
            self.jobs[rank]
                .send(RankJob::Run(Box::new(move |ctx: &mut RankCtx| {
                    let result =
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| (*f)(ctx)));
                    let _ = tx.send((ctx.rank(), result));
                })))
                .expect("resident rank thread died");
        }
        drop(tx);
        let mut slots: Vec<Option<R>> = (0..self.nprocs).map(|_| None).collect();
        let mut panicked: Option<Box<dyn std::any::Any + Send>> = None;
        for _ in 0..self.nprocs {
            let (rank, result) = rx.recv().expect("resident rank thread died mid-round");
            match result {
                Ok(v) => slots[rank] = Some(v),
                Err(payload) => {
                    if panicked.is_none() {
                        panicked = Some(payload);
                    }
                }
            }
        }
        if let Some(payload) = panicked {
            std::panic::resume_unwind(payload);
        }
        let results = slots
            .into_iter()
            .map(|s| s.expect("every rank reports exactly once"))
            .collect();
        let report = self.metrics.snapshot().since(&before);
        (results, report)
    }

    /// Cumulative traffic over the pool's whole life (every round so
    /// far).
    pub fn report(&self) -> FabricReport {
        self.metrics.snapshot()
    }
}

impl Drop for ResidentFabric {
    fn drop(&mut self) {
        for tx in &self.jobs {
            let _ = tx.send(RankJob::Stop);
        }
        for t in self.rank_threads.drain(..) {
            let _ = t.join();
        }
        for inj in self.injectors.iter().flatten() {
            let _ = inj.send(Outbound::Stop);
        }
        for t in self.injector_threads.drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_pass() {
        let results = Fabric::run(4, None, |ctx| {
            let next = (ctx.rank() + 1) % 4;
            ctx.send(next, super::super::USER_TAG_BASE, vec![ctx.rank() as u8]);
            let env = ctx.recv_any(super::super::USER_TAG_BASE);
            (env.src, env.bytes[0])
        });
        for (r, (src, val)) in results.iter().enumerate() {
            assert_eq!(*src, (r + 3) % 4);
            assert_eq!(*val as usize, (r + 3) % 4);
        }
    }

    #[test]
    fn tags_do_not_cross() {
        let t0 = super::super::USER_TAG_BASE;
        let results = Fabric::run(2, None, |ctx| {
            if ctx.rank() == 0 {
                ctx.send(1, t0 + 1, vec![1]);
                ctx.send(1, t0 + 2, vec![2]);
                0
            } else {
                // receive out of order: tag 2 first
                let a = ctx.recv_any(t0 + 2);
                let b = ctx.recv_any(t0 + 1);
                assert_eq!(a.bytes, vec![2]);
                assert_eq!(b.bytes, vec![1]);
                1
            }
        });
        assert_eq!(results, vec![0, 1]);
    }

    #[test]
    fn recv_from_filters_source() {
        let t = super::super::USER_TAG_BASE;
        Fabric::run(3, None, |ctx| {
            if ctx.rank() < 2 {
                ctx.send(2, t, vec![ctx.rank() as u8]);
            } else {
                let b = ctx.recv_from(1, t);
                assert_eq!(b.bytes, vec![1]);
                let a = ctx.recv_from(0, t);
                assert_eq!(a.bytes, vec![0]);
            }
        });
    }

    #[test]
    fn metrics_count_remote_and_local() {
        let t = super::super::USER_TAG_BASE;
        let (_, report) = Fabric::run_report(2, None, |ctx| {
            ctx.send(ctx.rank(), t, vec![0; 10]); // local
            ctx.send(1 - ctx.rank(), t, vec![0; 20]); // remote
            ctx.recv_from(ctx.rank(), t);
            ctx.recv_from(1 - ctx.rank(), t);
        });
        assert_eq!(report.messages, 4);
        assert_eq!(report.remote_messages, 2);
        assert_eq!(report.bytes, 60);
        assert_eq!(report.remote_bytes, 40);
    }

    #[test]
    fn wire_model_delays_but_delivers() {
        let t = super::super::USER_TAG_BASE;
        let wire = WireModel {
            topology: Topology::uniform(2, 0.005, 0.0),
            time_scale: 1.0,
        };
        let start = std::time::Instant::now();
        Fabric::run(2, Some(wire), |ctx| {
            let peer = 1 - ctx.rank();
            ctx.send(peer, t, vec![42]);
            let env = ctx.recv_any(t);
            assert_eq!(env.bytes, vec![42]);
        });
        assert!(start.elapsed() >= Duration::from_millis(5));
    }

    #[test]
    fn try_recv_is_nonblocking_and_tag_scoped() {
        let t = super::super::USER_TAG_BASE;
        Fabric::run(2, None, |ctx| {
            if ctx.rank() == 0 {
                // nothing delivered yet: must return None, not block
                assert!(ctx.try_recv(t + 1).is_none());
                ctx.send(1, t + 1, vec![7]);
                ctx.send(1, t + 2, vec![8]);
            } else {
                // spin until the tag-1 message arrives, via try_recv only
                let env = loop {
                    if let Some(e) = ctx.try_recv(t + 1) {
                        break e;
                    }
                    std::thread::yield_now();
                };
                assert_eq!(env.bytes, vec![7]);
                // the tag-2 message was buffered, not dropped
                let other = ctx.recv_any(t + 2);
                assert_eq!(other.bytes, vec![8]);
            }
        });
    }

    #[test]
    fn try_recv_checks_pending_buffer_first() {
        let t = super::super::USER_TAG_BASE;
        Fabric::run(2, None, |ctx| {
            if ctx.rank() == 0 {
                ctx.send(1, t + 1, vec![1]);
                ctx.send(1, t + 2, vec![2]);
            } else {
                // recv_any on tag 2 buffers the tag-1 message in pending
                let b = ctx.recv_any(t + 2);
                assert_eq!(b.bytes, vec![2]);
                let a = ctx.try_recv(t + 1).expect("buffered message must be found");
                assert_eq!(a.bytes, vec![1]);
            }
        });
    }

    #[test]
    fn single_rank_fabric() {
        let t = super::super::USER_TAG_BASE;
        let r = Fabric::run(1, None, |ctx| {
            ctx.send(0, t, vec![9]);
            ctx.recv_any(t).bytes[0]
        });
        assert_eq!(r, vec![9]);
    }

    #[test]
    fn report_since_and_accumulate() {
        let before = FabricReport {
            messages: 2,
            remote_messages: 1,
            bytes: 100,
            remote_bytes: 60,
            arena_reuse_hits: 1,
            alloc_bytes_saved: 50,
        };
        let after = FabricReport {
            messages: 5,
            remote_messages: 3,
            bytes: 400,
            remote_bytes: 260,
            arena_reuse_hits: 4,
            alloc_bytes_saved: 170,
        };
        let delta = after.since(&before);
        assert_eq!(delta.messages, 3);
        assert_eq!(delta.remote_messages, 2);
        assert_eq!(delta.bytes, 300);
        assert_eq!(delta.remote_bytes, 200);
        assert_eq!(delta.arena_reuse_hits, 3);
        assert_eq!(delta.alloc_bytes_saved, 120);
        // counter wrap/reset saturates instead of panicking
        assert_eq!(before.since(&after), FabricReport::default());
        let mut total = before;
        total.accumulate(&delta);
        assert_eq!(total, after);
    }

    #[test]
    fn resident_rounds_reuse_the_pool_and_report_deltas() {
        let pool = ResidentFabric::new(4, None);
        for round in 0..3u8 {
            let (results, report) = pool.run_report(move |ctx| {
                let next = (ctx.rank() + 1) % 4;
                let tag = ctx.next_user_tag();
                ctx.send(next, tag, vec![round, ctx.rank() as u8]);
                let env = ctx.recv_any(tag);
                (env.bytes[0], env.bytes[1] as usize)
            });
            for (r, (got_round, src)) in results.iter().enumerate() {
                assert_eq!(*got_round, round);
                assert_eq!(*src, (r + 3) % 4);
            }
            // per-round delta: exactly this round's 4 messages
            assert_eq!(report.messages, 4);
            assert_eq!(report.remote_messages, 4);
        }
        // cumulative report spans every round
        assert_eq!(pool.report().messages, 12);
    }

    #[test]
    fn resident_round_results_come_back_in_rank_order() {
        let pool = ResidentFabric::new(3, None);
        let results = pool.run(|ctx| ctx.rank() * 10);
        assert_eq!(results, vec![0, 10, 20]);
    }

    #[test]
    fn resident_pool_survives_a_panicked_round() {
        let pool = ResidentFabric::new(2, None);
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(|ctx| {
                // no communication: panic before any exchange so peers
                // cannot be left blocked
                if ctx.rank() == 1 {
                    panic!("rank 1 exploded");
                }
                ctx.rank()
            })
        }));
        assert!(boom.is_err(), "the round's panic must propagate");
        // the pool still serves later rounds
        let results = pool.run(|ctx| ctx.rank() + 100);
        assert_eq!(results, vec![100, 101]);
    }

    #[test]
    fn flush_user_backlog_drops_only_stale_tags() {
        let pool = ResidentFabric::new(2, None);
        // round 1: rank 0 sends a message rank 1 NEVER consumes (an
        // errored round's straggler)
        pool.run(|ctx| {
            let tag = ctx.next_user_tag();
            if ctx.rank() == 0 {
                ctx.send(1, tag, vec![7]);
            }
        });
        // round 2: the stale envelope is flushed; fresh traffic flows
        let results = pool.run(|ctx| {
            ctx.flush_user_backlog();
            let tag = ctx.next_user_tag();
            let peer = 1 - ctx.rank();
            ctx.send(peer, tag, vec![ctx.rank() as u8]);
            let env = ctx.recv_any(tag);
            env.bytes[0]
        });
        assert_eq!(results, vec![1, 0]);
        // round 3: rank 1's pending buffer holds nothing stale — a
        // recv_any on a fresh tag would hang if flush had dropped live
        // traffic, and the stale vec![7] must not resurface
        let leftovers = pool.run(|ctx| {
            ctx.flush_user_backlog();
            let tag = ctx.next_user_tag();
            let peer = 1 - ctx.rank();
            ctx.send(peer, tag, vec![41 + ctx.rank() as u8]);
            ctx.recv_any(tag).bytes[0]
        });
        assert_eq!(leftovers, vec![42, 41]);
    }

    #[test]
    fn recv_any_deadline_times_out_then_recovers() {
        let t = super::super::USER_TAG_BASE;
        Fabric::run(2, None, |ctx| {
            if ctx.rank() == 0 {
                // nothing in flight yet: a short deadline must elapse
                let before = Instant::now();
                let got = ctx.recv_any_deadline(t + 1, Instant::now() + Duration::from_millis(20));
                assert!(got.is_none(), "nothing was sent; must time out");
                assert!(before.elapsed() >= Duration::from_millis(20));
                ctx.send(1, t + 2, vec![1]);
                // the peer's reply arrives well inside this deadline
                let env = ctx
                    .recv_any_deadline(t + 3, Instant::now() + Duration::from_secs(5))
                    .expect("reply must arrive before the deadline");
                assert_eq!(env.bytes, vec![3]);
            } else {
                let env = ctx.recv_any(t + 2);
                assert_eq!(env.bytes, vec![1]);
                ctx.send(0, t + 3, vec![3]);
            }
        });
    }

    #[test]
    fn recv_any_deadline_drains_already_delivered_traffic_past_the_deadline() {
        let t = super::super::USER_TAG_BASE;
        Fabric::run(2, None, |ctx| {
            if ctx.rank() == 0 {
                ctx.send(1, t + 1, vec![9]);
            } else {
                // wait until the message is certainly delivered, then ask
                // with an ALREADY-EXPIRED deadline: delivered traffic must
                // still be consumed, only missing traffic times out
                let env = ctx.recv_any(t + 1);
                ctx.pending.push_back(env);
                let got = ctx
                    .recv_any_deadline(t + 1, Instant::now() - Duration::from_secs(1))
                    .expect("already-delivered envelope must be drained");
                assert_eq!(got.bytes, vec![9]);
            }
        });
    }

    #[test]
    fn fault_injector_drops_and_corrupts_counted_sends() {
        let faults = Arc::new(FaultInjector::new(2));
        faults.drop_next_sends(0, 1);
        faults.corrupt_next_sends(0, 1);
        let pool = ResidentFabric::with_faults(2, None, Some(faults.clone()));
        let results = pool.run(|ctx| {
            let tag = ctx.next_user_tag();
            if ctx.rank() == 0 {
                ctx.send(1, tag, vec![1, 2, 3, 4]); // swallowed
                ctx.send(1, tag, vec![5, 6, 7, 8]); // truncated to 3 bytes
                ctx.send(1, tag, vec![9, 10]); // clean
                Vec::new()
            } else {
                let first = ctx.recv_any(tag);
                let second = ctx.recv_any(tag);
                vec![first.bytes, second.bytes]
            }
        });
        assert_eq!(
            results[1],
            vec![vec![5, 6, 7], vec![9, 10]],
            "the dropped send never arrives; the corrupted one is one byte short"
        );
        assert_eq!(faults.drops_injected(), 1);
        assert_eq!(faults.corruptions_injected(), 1);
        // clearing disarms everything: the next round is fault-free
        faults.clear();
        let clean = pool.run(|ctx| {
            ctx.flush_user_backlog();
            let tag = ctx.next_user_tag();
            let peer = 1 - ctx.rank();
            ctx.send(peer, tag, vec![7]);
            ctx.recv_any(tag).bytes[0]
        });
        assert_eq!(clean, vec![7, 7]);
    }

    #[test]
    fn fault_injector_delay_slows_sends() {
        let faults = Arc::new(FaultInjector::new(2));
        faults.delay_sends(0, Duration::from_millis(10));
        let pool = ResidentFabric::with_faults(2, None, Some(faults.clone()));
        let start = Instant::now();
        let results = pool.run(|ctx| {
            let tag = ctx.next_user_tag();
            if ctx.rank() == 0 {
                ctx.send(1, tag, vec![1]);
                0
            } else {
                ctx.recv_any(tag).bytes[0]
            }
        });
        assert_eq!(results, vec![0, 1]);
        assert!(start.elapsed() >= Duration::from_millis(10));
        assert!(faults.delays_injected() >= 1);
    }

    #[test]
    fn fault_injector_corrupt_makes_empty_payloads_nonempty() {
        let faults = Arc::new(FaultInjector::new(2));
        faults.corrupt_next_sends(1, 1);
        let pool = ResidentFabric::with_faults(2, None, Some(faults));
        let results = pool.run(|ctx| {
            let tag = ctx.next_user_tag();
            if ctx.rank() == 1 {
                ctx.send(0, tag, Vec::new()); // empty placeholder, corrupted
                0
            } else {
                ctx.recv_any(tag).bytes.len()
            }
        });
        assert_eq!(results[0], 1, "an empty payload grows a garbage byte");
    }

    #[test]
    fn live_rank_threads_tracks_resident_pools() {
        // other tests may hold pools concurrently, so only relative
        // bounds are safe here; the exact 0-after-drop check lives in
        // tests/server_soak.rs, which serializes itself
        let pool = ResidentFabric::new(3, None);
        assert!(live_rank_threads() >= 3, "our 3 resident threads are alive");
        drop(pool);
        // our 3 threads are joined; the counter cannot still include them
        // (other tests may have added/removed their own in the meantime,
        // so no exact assertion)
    }

    #[test]
    fn resident_fabric_with_wire_model_delivers() {
        let wire = WireModel {
            topology: Topology::uniform(2, 0.001, 0.0),
            time_scale: 1.0,
        };
        let pool = ResidentFabric::new(2, Some(wire));
        for _ in 0..2 {
            let results = pool.run(|ctx| {
                let tag = ctx.next_user_tag();
                let peer = 1 - ctx.rank();
                ctx.send(peer, tag, vec![5]);
                ctx.recv_any(tag).bytes[0]
            });
            assert_eq!(results, vec![5, 5]);
        }
    }
}
