//! The rank fabric: threads + mailboxes + optional wire delays.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

use crate::layout::Rank;

use super::topology::Topology;

/// One message in flight. `tag` disambiguates concurrent exchanges
/// (collectives use tags below [`super::USER_TAG_BASE`]).
#[derive(Debug)]
pub struct Envelope {
    pub src: Rank,
    pub tag: u64,
    pub bytes: Vec<u8>,
}

/// Wire-delay model: when enabled, each message is delivered by the
/// sender's injector ("NIC") thread after `latency + bytes·per_byte`
/// seconds, serialised per source — a non-blocking `Isend` whose payload
/// arrives later, so communication–computation overlap is measurable in
/// real time (ablation_overlap bench).
#[derive(Clone, Debug)]
pub struct WireModel {
    pub topology: Topology,
    /// Scale factor: modeled seconds → real sleep seconds.
    pub time_scale: f64,
}

/// Fabric-wide counters (atomics: written by all rank threads).
#[derive(Debug, Default)]
pub struct FabricMetrics {
    pub messages: AtomicU64,
    pub remote_messages: AtomicU64,
    pub bytes: AtomicU64,
    pub remote_bytes: AtomicU64,
}

impl FabricMetrics {
    fn record(&self, src: Rank, dst: Rank, len: usize) {
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(len as u64, Ordering::Relaxed);
        if src != dst {
            self.remote_messages.fetch_add(1, Ordering::Relaxed);
            self.remote_bytes.fetch_add(len as u64, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> FabricReport {
        FabricReport {
            messages: self.messages.load(Ordering::Relaxed),
            remote_messages: self.remote_messages.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            remote_bytes: self.remote_bytes.load(Ordering::Relaxed),
        }
    }
}

/// Immutable summary of a fabric run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FabricReport {
    pub messages: u64,
    pub remote_messages: u64,
    pub bytes: u64,
    pub remote_bytes: u64,
}

enum Outbound {
    Msg { dst: Rank, env: Envelope },
    Stop,
}

/// Per-rank handle: the MPI communicator analogue.
pub struct RankCtx {
    rank: Rank,
    nprocs: usize,
    mailboxes: Vec<Sender<Envelope>>,
    injector: Option<Sender<Outbound>>,
    rx: Receiver<Envelope>,
    pending: VecDeque<Envelope>,
    metrics: Arc<FabricMetrics>,
    pub(super) collective_gen: u64,
    user_gen: u64,
}

impl RankCtx {
    pub fn rank(&self) -> Rank {
        self.rank
    }

    pub fn nprocs(&self) -> usize {
        self.nprocs
    }

    pub fn metrics(&self) -> &FabricMetrics {
        &self.metrics
    }

    /// Fresh tag for one engine-level exchange. SPMD contract: every rank
    /// calls this in the same order, so tags agree across ranks and
    /// back-to-back exchanges can never interleave.
    pub fn next_user_tag(&mut self) -> u64 {
        self.user_gen += 1;
        super::USER_TAG_BASE + self.user_gen
    }

    /// Non-blocking send (MPI_Isend analogue): enqueues and returns. The
    /// payload is moved, not copied.
    pub fn send(&self, dst: Rank, tag: u64, bytes: Vec<u8>) {
        self.metrics.record(self.rank, dst, bytes.len());
        let env = Envelope {
            src: self.rank,
            tag,
            bytes,
        };
        match (&self.injector, dst == self.rank) {
            // local sends bypass the wire even under a wire model
            (Some(inj), false) => inj
                .send(Outbound::Msg { dst, env })
                .expect("injector thread died"),
            _ => self.mailboxes[dst].send(env).expect("destination rank died"),
        }
    }

    /// Blocking receive of the next message with tag `tag`, from anyone
    /// (MPI_Waitany analogue). Other tags are buffered, not lost.
    pub fn recv_any(&mut self, tag: u64) -> Envelope {
        if let Some(pos) = self.pending.iter().position(|e| e.tag == tag) {
            return self.pending.remove(pos).unwrap();
        }
        loop {
            let env = self.rx.recv().expect("fabric closed while receiving");
            if env.tag == tag {
                return env;
            }
            self.pending.push_back(env);
        }
    }

    /// Non-blocking receive (MPI_Iprobe + MPI_Recv analogue): the next
    /// already-delivered message with tag `tag`, from anyone, or `None`
    /// when nothing with that tag has arrived yet. Other tags are
    /// buffered, not lost. The pipelined executor drains arrivals with
    /// this between posting sends, so early packages are unpacked while
    /// later packages are still being packed.
    pub fn try_recv(&mut self, tag: u64) -> Option<Envelope> {
        if let Some(pos) = self.pending.iter().position(|e| e.tag == tag) {
            return self.pending.remove(pos);
        }
        loop {
            match self.rx.try_recv() {
                Ok(env) if env.tag == tag => return Some(env),
                Ok(env) => self.pending.push_back(env),
                Err(_) => return None,
            }
        }
    }

    /// Blocking receive from a specific source and tag.
    pub fn recv_from(&mut self, src: Rank, tag: u64) -> Envelope {
        if let Some(pos) = self
            .pending
            .iter()
            .position(|e| e.tag == tag && e.src == src)
        {
            return self.pending.remove(pos).unwrap();
        }
        loop {
            let env = self.rx.recv().expect("fabric closed while receiving");
            if env.tag == tag && env.src == src {
                return env;
            }
            self.pending.push_back(env);
        }
    }
}

/// The fabric launcher.
pub struct Fabric;

impl Fabric {
    /// Run `f` on `nprocs` rank threads; returns per-rank results in rank
    /// order. Panics in any rank propagate.
    pub fn run<R: Send>(
        nprocs: usize,
        wire: Option<WireModel>,
        f: impl Fn(&mut RankCtx) -> R + Send + Sync,
    ) -> Vec<R> {
        Self::run_report(nprocs, wire, f).0
    }

    /// Like [`Fabric::run`], also returning the traffic report.
    pub fn run_report<R: Send>(
        nprocs: usize,
        wire: Option<WireModel>,
        f: impl Fn(&mut RankCtx) -> R + Send + Sync,
    ) -> (Vec<R>, FabricReport) {
        assert!(nprocs > 0);
        let metrics = Arc::new(FabricMetrics::default());
        let mut mailboxes = Vec::with_capacity(nprocs);
        let mut rxs = Vec::with_capacity(nprocs);
        for _ in 0..nprocs {
            let (tx, rx) = channel::<Envelope>();
            mailboxes.push(tx);
            rxs.push(rx);
        }

        // Injector ("NIC") threads, one per source rank, FIFO per source.
        let mut injectors: Vec<Option<Sender<Outbound>>> = vec![None; nprocs];
        let mut injector_threads = Vec::new();
        if let Some(w) = &wire {
            for src in 0..nprocs {
                let (tx, rx) = channel::<Outbound>();
                injectors[src] = Some(tx);
                let boxes = mailboxes.clone();
                let topo = w.topology.clone();
                let scale = w.time_scale;
                injector_threads.push(std::thread::spawn(move || {
                    while let Ok(Outbound::Msg { dst, env }) = rx.recv() {
                        let secs =
                            topo.link_cost(src, dst, env.bytes.len() as u64) * scale;
                        if secs > 0.0 {
                            std::thread::sleep(Duration::from_secs_f64(secs));
                        }
                        if boxes[dst].send(env).is_err() {
                            break; // receiver done — drop late traffic
                        }
                    }
                }));
            }
        }

        let results: Vec<R> = std::thread::scope(|scope| {
            let handles: Vec<_> = rxs
                .into_iter()
                .enumerate()
                .map(|(rank, rx)| {
                    let mut ctx = RankCtx {
                        rank,
                        nprocs,
                        mailboxes: mailboxes.clone(),
                        injector: injectors[rank].clone(),
                        rx,
                        pending: VecDeque::new(),
                        metrics: metrics.clone(),
                        collective_gen: 0,
                        user_gen: 0,
                    };
                    let f = &f;
                    scope.spawn(move || f(&mut ctx))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    // re-raise the ORIGINAL panic payload so callers (and
                    // should_panic tests) see the real failure message
                    h.join()
                        .unwrap_or_else(|e| std::panic::resume_unwind(e))
                })
                .collect()
        });

        for inj in injectors.iter().flatten() {
            let _ = inj.send(Outbound::Stop);
        }
        drop(injectors);
        for t in injector_threads {
            let _ = t.join();
        }
        let report = metrics.snapshot();
        (results, report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_pass() {
        let results = Fabric::run(4, None, |ctx| {
            let next = (ctx.rank() + 1) % 4;
            ctx.send(next, super::super::USER_TAG_BASE, vec![ctx.rank() as u8]);
            let env = ctx.recv_any(super::super::USER_TAG_BASE);
            (env.src, env.bytes[0])
        });
        for (r, (src, val)) in results.iter().enumerate() {
            assert_eq!(*src, (r + 3) % 4);
            assert_eq!(*val as usize, (r + 3) % 4);
        }
    }

    #[test]
    fn tags_do_not_cross() {
        let t0 = super::super::USER_TAG_BASE;
        let results = Fabric::run(2, None, |ctx| {
            if ctx.rank() == 0 {
                ctx.send(1, t0 + 1, vec![1]);
                ctx.send(1, t0 + 2, vec![2]);
                0
            } else {
                // receive out of order: tag 2 first
                let a = ctx.recv_any(t0 + 2);
                let b = ctx.recv_any(t0 + 1);
                assert_eq!(a.bytes, vec![2]);
                assert_eq!(b.bytes, vec![1]);
                1
            }
        });
        assert_eq!(results, vec![0, 1]);
    }

    #[test]
    fn recv_from_filters_source() {
        let t = super::super::USER_TAG_BASE;
        Fabric::run(3, None, |ctx| {
            if ctx.rank() < 2 {
                ctx.send(2, t, vec![ctx.rank() as u8]);
            } else {
                let b = ctx.recv_from(1, t);
                assert_eq!(b.bytes, vec![1]);
                let a = ctx.recv_from(0, t);
                assert_eq!(a.bytes, vec![0]);
            }
        });
    }

    #[test]
    fn metrics_count_remote_and_local() {
        let t = super::super::USER_TAG_BASE;
        let (_, report) = Fabric::run_report(2, None, |ctx| {
            ctx.send(ctx.rank(), t, vec![0; 10]); // local
            ctx.send(1 - ctx.rank(), t, vec![0; 20]); // remote
            ctx.recv_from(ctx.rank(), t);
            ctx.recv_from(1 - ctx.rank(), t);
        });
        assert_eq!(report.messages, 4);
        assert_eq!(report.remote_messages, 2);
        assert_eq!(report.bytes, 60);
        assert_eq!(report.remote_bytes, 40);
    }

    #[test]
    fn wire_model_delays_but_delivers() {
        let t = super::super::USER_TAG_BASE;
        let wire = WireModel {
            topology: Topology::uniform(2, 0.005, 0.0),
            time_scale: 1.0,
        };
        let start = std::time::Instant::now();
        Fabric::run(2, Some(wire), |ctx| {
            let peer = 1 - ctx.rank();
            ctx.send(peer, t, vec![42]);
            let env = ctx.recv_any(t);
            assert_eq!(env.bytes, vec![42]);
        });
        assert!(start.elapsed() >= Duration::from_millis(5));
    }

    #[test]
    fn try_recv_is_nonblocking_and_tag_scoped() {
        let t = super::super::USER_TAG_BASE;
        Fabric::run(2, None, |ctx| {
            if ctx.rank() == 0 {
                // nothing delivered yet: must return None, not block
                assert!(ctx.try_recv(t + 1).is_none());
                ctx.send(1, t + 1, vec![7]);
                ctx.send(1, t + 2, vec![8]);
            } else {
                // spin until the tag-1 message arrives, via try_recv only
                let env = loop {
                    if let Some(e) = ctx.try_recv(t + 1) {
                        break e;
                    }
                    std::thread::yield_now();
                };
                assert_eq!(env.bytes, vec![7]);
                // the tag-2 message was buffered, not dropped
                let other = ctx.recv_any(t + 2);
                assert_eq!(other.bytes, vec![8]);
            }
        });
    }

    #[test]
    fn try_recv_checks_pending_buffer_first() {
        let t = super::super::USER_TAG_BASE;
        Fabric::run(2, None, |ctx| {
            if ctx.rank() == 0 {
                ctx.send(1, t + 1, vec![1]);
                ctx.send(1, t + 2, vec![2]);
            } else {
                // recv_any on tag 2 buffers the tag-1 message in pending
                let b = ctx.recv_any(t + 2);
                assert_eq!(b.bytes, vec![2]);
                let a = ctx.try_recv(t + 1).expect("buffered message must be found");
                assert_eq!(a.bytes, vec![1]);
            }
        });
    }

    #[test]
    fn single_rank_fabric() {
        let t = super::super::USER_TAG_BASE;
        let r = Fabric::run(1, None, |ctx| {
            ctx.send(0, t, vec![9]);
            ctx.recv_any(t).bytes[0]
        });
        assert_eq!(r, vec![9]);
    }
}
