//! Simulated message-passing fabric — the MPI stand-in (DESIGN.md §2).
//!
//! COSTA's claims are about which bytes move between which ranks and how
//! packing/overlap hide latency. Both are exercised faithfully by an
//! in-process fabric: each *rank* is an OS thread with a mailbox;
//! [`RankCtx::send`] is a non-blocking `MPI_Isend` analogue,
//! [`RankCtx::recv_any`] is `MPI_Waitany` over posted receives. An
//! optional [`WireModel`] adds per-link latency/bandwidth delays (injector
//! threads play the NIC), making communication–computation overlap
//! measurable in real time; independently, a [`clock`] ledger accounts
//! modeled cost analytically.

mod clock;
mod collective;
mod fabric;
mod topology;

pub use clock::SimClock;
pub use fabric::{Envelope, Fabric, FabricMetrics, FabricReport, RankCtx, WireModel};
pub use topology::Topology;

/// Tags below this are reserved for collectives (barrier/allgather).
pub(crate) const USER_TAG_BASE: u64 = 1 << 32;
