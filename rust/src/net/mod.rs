//! Simulated message-passing fabric — the MPI stand-in (DESIGN.md §2).
//!
//! COSTA's claims (paper §6 "Implementation", §7 "Benchmarks") are about
//! which bytes move between which ranks and how packing/overlap hide
//! latency. Both are exercised faithfully by an in-process fabric: each
//! *rank* is an OS thread with a mailbox;
//! [`RankCtx::send`] is a non-blocking `MPI_Isend` analogue,
//! [`RankCtx::recv_any`] is `MPI_Waitany` over posted receives, and
//! [`RankCtx::try_recv`] is the `MPI_Iprobe`-style non-blocking receive
//! the pipelined executor drains between sends — the §6 asynchronous
//! send / wait-any receive pattern of Algorithm 3. The
//! [`Topology`] type is the paper §3 "Network Topology" latency/bandwidth
//! table (heterogeneous links supported, per the abstract's claim). An
//! optional [`WireModel`] adds per-link latency/bandwidth delays (injector
//! threads play the NIC), making communication–computation overlap
//! measurable in real time; independently, a [`SimClock`] ledger accounts
//! modeled cost analytically.
//!
//! For serving workloads, [`ResidentFabric`] keeps the rank threads
//! alive between closures (a persistent pool with per-rank job
//! mailboxes and per-round [`FabricReport`] snapshots) — the substrate
//! the [`TransformServer`](crate::server::TransformServer) runs on.
//!
//! For verification, [`Fabric::run_scripted`] replaces the NIC injectors
//! with a deterministic router that releases user-tagged envelopes to
//! each receiver in a forced [`DeliverySchedule`] order — the substrate
//! the delivery-order model checker
//! ([`crate::analysis::check_transform`]) enumerates interleavings on.

mod clock;
mod collective;
mod fabric;
mod topology;

pub use clock::SimClock;
pub use fabric::{
    live_rank_threads, DeliveryLog, DeliverySchedule, Envelope, Fabric, FabricMetrics,
    FabricReport, FaultInjector, RankCtx, ResidentFabric, WireModel,
};
pub use topology::Topology;

/// Tags below this are reserved for collectives (barrier/allgather);
/// engine-level exchanges draw tags from [`RankCtx::next_user_tag`],
/// which starts above it.
pub const USER_TAG_BASE: u64 = 1 << 32;
