//! Network topologies: per-link latency and per-element transfer cost.
//!
//! The paper (§3, "Network Topology") models heterogeneous networks with a
//! bandwidth–latency family `w = L(p_i,p_j) + B(p_i,p_j)·V(s)`; this type
//! is the `L`/`B` table. Units are abstract cost units for COPR (only
//! ratios matter) and seconds when used as a [`super::WireModel`].

use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct Topology {
    n: usize,
    lat: Vec<f64>,      // n x n latency
    per_elem: Vec<f64>, // n x n cost per element
}

impl Topology {
    pub fn new(n: usize, lat: Vec<f64>, per_elem: Vec<f64>) -> Self {
        assert_eq!(lat.len(), n * n);
        assert_eq!(per_elem.len(), n * n);
        Topology { n, lat, per_elem }
    }

    /// Zero-cost links: use when only volumes matter (tests, Fig. 3).
    pub fn flat(n: usize) -> Self {
        Self::uniform(n, 0.0, 0.0)
    }

    /// All links identical.
    pub fn uniform(n: usize, latency: f64, per_elem: f64) -> Self {
        Topology {
            n,
            lat: vec![latency; n * n],
            per_elem: vec![per_elem; n * n],
        }
    }

    /// Two-level (node/network) topology: ranks in groups of
    /// `per_node`; intra-node links are cheap, inter-node expensive —
    /// the Piz-Daint-like shape COPR exploits on real machines.
    pub fn two_level(
        n: usize,
        per_node: usize,
        intra: (f64, f64),
        inter: (f64, f64),
    ) -> Self {
        assert!(per_node > 0);
        let mut lat = vec![0.0; n * n];
        let mut per = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let same = i / per_node == j / per_node;
                let (l, b) = if same { intra } else { inter };
                lat[i * n + j] = l;
                per[i * n + j] = b;
            }
        }
        Topology { n, lat, per_elem: per }
    }

    /// MPI-like wire parameters for the [`super::WireModel`]: 5 µs
    /// message latency, 10 GB/s links (per-BYTE cost — the fabric passes
    /// payload bytes as the volume). Matches commodity-interconnect
    /// magnitudes; the Fig. 2/4 benches run under this model so that
    /// eager per-block messaging pays its real latency bill.
    pub fn mpi_like(n: usize) -> Self {
        Self::uniform(n, 5e-6, 1e-10)
    }

    /// Random symmetric heterogeneous topology (tests / Lemma-1 sweeps).
    pub fn random(n: usize, rng: &mut Rng) -> Self {
        let mut lat = vec![0.0; n * n];
        let mut per = vec![0.0; n * n];
        for i in 0..n {
            for j in (i + 1)..n {
                let l = rng.f64_in(0.1, 10.0);
                let b = rng.f64_in(0.01, 1.0);
                lat[i * n + j] = l;
                lat[j * n + i] = l;
                per[i * n + j] = b;
                per[j * n + i] = b;
            }
        }
        Topology { n, lat, per_elem: per }
    }

    pub fn nprocs(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn latency(&self, i: usize, j: usize) -> f64 {
        self.lat[i * self.n + j]
    }

    #[inline]
    pub fn per_element(&self, i: usize, j: usize) -> f64 {
        self.per_elem[i * self.n + j]
    }

    /// Cost of moving `volume` elements across link (i, j).
    pub fn link_cost(&self, i: usize, j: usize, volume: u64) -> f64 {
        if i == j {
            0.0
        } else {
            self.latency(i, j) + self.per_element(i, j) * volume as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_links() {
        let t = Topology::uniform(3, 2.0, 0.5);
        assert_eq!(t.latency(0, 2), 2.0);
        assert_eq!(t.per_element(1, 0), 0.5);
        assert_eq!(t.link_cost(0, 1, 10), 7.0);
        assert_eq!(t.link_cost(1, 1, 10), 0.0);
    }

    #[test]
    fn two_level_split() {
        let t = Topology::two_level(4, 2, (1.0, 0.1), (10.0, 1.0));
        assert_eq!(t.latency(0, 1), 1.0); // same node
        assert_eq!(t.latency(0, 2), 10.0); // cross node
        assert_eq!(t.per_element(2, 3), 0.1);
        assert_eq!(t.per_element(1, 2), 1.0);
    }

    #[test]
    fn random_is_symmetric() {
        let mut rng = crate::util::Rng::new(7);
        let t = Topology::random(5, &mut rng);
        for i in 0..5 {
            for j in 0..5 {
                assert_eq!(t.latency(i, j), t.latency(j, i));
                assert_eq!(t.per_element(i, j), t.per_element(j, i));
            }
        }
    }

    #[test]
    fn flat_is_free() {
        let t = Topology::flat(3);
        assert_eq!(t.link_cost(0, 2, 1_000_000), 0.0);
    }
}
