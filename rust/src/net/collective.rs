//! Minimal collectives over the fabric: barrier, broadcast, allgather,
//! and an elementwise f32 reduce — just enough for the drivers
//! (scalapack baseline, cosma GEMM, rpa). Tags are drawn from the
//! reserved sub-[`super::USER_TAG_BASE`] space, versioned by a per-rank
//! generation counter so back-to-back collectives cannot collide.

use super::fabric::RankCtx;

const KIND_BARRIER: u64 = 0;
const KIND_BCAST: u64 = 1;
const KIND_GATHER: u64 = 2;
const KIND_REDUCE: u64 = 3;

impl RankCtx {
    fn collective_tag(&mut self, kind: u64) -> u64 {
        self.collective_gen += 1;
        debug_assert!(self.collective_gen < (1 << 28));
        (kind << 28) | self.collective_gen
    }

    /// Central-coordinator barrier: everyone reports to rank 0, rank 0
    /// releases everyone. Two message rounds; O(n) messages.
    pub fn barrier(&mut self) {
        let tag = self.collective_tag(KIND_BARRIER);
        let n = self.nprocs();
        if n == 1 {
            return;
        }
        if self.rank() == 0 {
            for src in 1..n {
                self.recv_from(src, tag);
            }
            for dst in 1..n {
                self.send(dst, tag, Vec::new());
            }
        } else {
            self.send(0, tag, Vec::new());
            self.recv_from(0, tag);
        }
    }

    /// Broadcast `bytes` from `root`; returns the payload on every rank.
    pub fn broadcast(&mut self, root: usize, bytes: Vec<u8>) -> Vec<u8> {
        let tag = self.collective_tag(KIND_BCAST);
        if self.nprocs() == 1 {
            return bytes;
        }
        if self.rank() == root {
            for dst in 0..self.nprocs() {
                if dst != root {
                    self.send(dst, tag, bytes.clone());
                }
            }
            bytes
        } else {
            self.recv_from(root, tag).bytes
        }
    }

    /// Allgather: every rank contributes `bytes`; returns all
    /// contributions in rank order. Naive all-to-all (n^2 messages) —
    /// used only on small control payloads.
    pub fn allgather(&mut self, bytes: Vec<u8>) -> Vec<Vec<u8>> {
        let tag = self.collective_tag(KIND_GATHER);
        let n = self.nprocs();
        let me = self.rank();
        for dst in 0..n {
            if dst != me {
                self.send(dst, tag, bytes.clone());
            }
        }
        let mut out: Vec<Vec<u8>> = vec![Vec::new(); n];
        out[me] = bytes;
        for src in 0..n {
            if src != me {
                out[src] = self.recv_from(src, tag).bytes;
            }
        }
        out
    }

    /// Elementwise f32 sum-reduce to `root`: every rank contributes a
    /// slice of equal length; root receives the sum. Tree-free (root
    /// accumulates) — fine for the small C panels the drivers reduce.
    pub fn reduce_sum_f32(&mut self, root: usize, data: &[f32]) -> Option<Vec<f32>> {
        let tag = self.collective_tag(KIND_REDUCE);
        let n = self.nprocs();
        if self.rank() == root {
            let mut acc = data.to_vec();
            for _ in 0..n - 1 {
                let env = self.recv_any(tag);
                let remote = bytes_to_f32(&env.bytes);
                assert_eq!(remote.len(), acc.len(), "reduce length mismatch");
                for (a, r) in acc.iter_mut().zip(remote) {
                    *a += r;
                }
            }
            Some(acc)
        } else {
            self.send(root, tag, f32_to_bytes(data));
            None
        }
    }
}

pub fn f32_to_bytes(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 4);
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

pub fn bytes_to_f32(bytes: &[u8]) -> Vec<f32> {
    assert_eq!(bytes.len() % 4, 0);
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::fabric::Fabric;
    use super::*;

    #[test]
    fn barrier_completes() {
        Fabric::run(5, None, |ctx| {
            for _ in 0..3 {
                ctx.barrier();
            }
        });
    }

    #[test]
    fn broadcast_delivers_to_all() {
        let r = Fabric::run(4, None, |ctx| {
            let payload = if ctx.rank() == 2 { vec![7, 8, 9] } else { Vec::new() };
            ctx.broadcast(2, payload)
        });
        for x in r {
            assert_eq!(x, vec![7, 8, 9]);
        }
    }

    #[test]
    fn allgather_orders_by_rank() {
        let r = Fabric::run(3, None, |ctx| ctx.allgather(vec![ctx.rank() as u8]));
        for per_rank in r {
            assert_eq!(per_rank, vec![vec![0], vec![1], vec![2]]);
        }
    }

    #[test]
    fn reduce_sums_elementwise() {
        let r = Fabric::run(4, None, |ctx| {
            let mine = vec![ctx.rank() as f32, 1.0];
            ctx.reduce_sum_f32(0, &mine)
        });
        assert_eq!(r[0].as_ref().unwrap(), &vec![0.0 + 1.0 + 2.0 + 3.0, 4.0]);
        assert!(r[1].is_none());
    }

    #[test]
    fn f32_bytes_roundtrip() {
        let v = vec![1.5f32, -2.25, 0.0, f32::MAX];
        assert_eq!(bytes_to_f32(&f32_to_bytes(&v)), v);
    }

    #[test]
    fn mixed_collectives_do_not_collide() {
        let r = Fabric::run(3, None, |ctx| {
            ctx.barrier();
            let g = ctx.allgather(vec![ctx.rank() as u8 + 1]);
            ctx.barrier();
            let b = ctx.broadcast(1, vec![g[2][0]]);
            b[0]
        });
        assert_eq!(r, vec![3, 3, 3]);
    }
}
