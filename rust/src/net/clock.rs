//! Analytic time model: given a set of transfers and a topology, estimate
//! per-rank busy time and the makespan under a simple postal model where
//! each rank's sends and receives serialise at its NIC but distinct ranks
//! proceed in parallel. Used for modeled-time columns in reports (the
//! wall-clock of the in-process fabric is measured separately).

use crate::layout::Rank;

use super::topology::Topology;

#[derive(Clone, Debug, Default)]
pub struct SimClock {
    transfers: Vec<(Rank, Rank, u64)>, // (src, dst, elements)
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, src: Rank, dst: Rank, elements: u64) {
        self.transfers.push((src, dst, elements));
    }

    pub fn transfer_count(&self) -> usize {
        self.transfers.len()
    }

    /// Total modeled link cost (sum over transfers; local = free).
    pub fn total_cost(&self, topo: &Topology) -> f64 {
        self.transfers
            .iter()
            .map(|&(s, d, v)| topo.link_cost(s, d, v))
            .sum()
    }

    /// Postal-model makespan: each rank pays for its own sends and its
    /// own receives; the job finishes when the busiest rank does.
    pub fn makespan(&self, topo: &Topology, nprocs: usize) -> f64 {
        let mut busy = vec![0.0f64; nprocs];
        for &(s, d, v) in &self.transfers {
            let c = topo.link_cost(s, d, v);
            if c > 0.0 {
                busy[s] += c;
                busy[d] += c;
            }
        }
        busy.iter().cloned().fold(0.0, f64::max)
    }

    /// Remote transfer volume in elements.
    pub fn remote_volume(&self) -> u64 {
        self.transfers
            .iter()
            .filter(|&&(s, d, _)| s != d)
            .map(|&(_, _, v)| v)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn costs_accumulate() {
        let mut c = SimClock::new();
        c.record(0, 1, 10);
        c.record(1, 1, 99); // local: free
        c.record(1, 2, 20);
        let t = Topology::uniform(3, 1.0, 0.5);
        assert_eq!(c.total_cost(&t), (1.0 + 5.0) + (1.0 + 10.0));
        assert_eq!(c.remote_volume(), 30);
        assert_eq!(c.transfer_count(), 3);
    }

    #[test]
    fn makespan_is_busiest_rank() {
        let mut c = SimClock::new();
        // rank 1 participates in both transfers -> busiest
        c.record(0, 1, 10);
        c.record(1, 2, 10);
        let t = Topology::uniform(3, 0.0, 1.0);
        assert_eq!(c.makespan(&t, 3), 20.0);
    }

    #[test]
    fn empty_clock_zero() {
        let c = SimClock::new();
        let t = Topology::uniform(2, 1.0, 1.0);
        assert_eq!(c.total_cost(&t), 0.0);
        assert_eq!(c.makespan(&t, 2), 0.0);
    }
}
