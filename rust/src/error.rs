//! Minimal error plumbing (anyhow-shaped, dependency-free).
//!
//! The offline crate set has no `anyhow`; this module provides the small
//! subset the crate uses — [`Error`], [`Result`], the `anyhow!`/`bail!`
//! macros and the [`Context`] extension trait — with compatible semantics:
//! `{:#}` (alternate) formatting prints the whole context chain
//! outermost-first, `{}` prints only the outermost message.

use std::fmt;

/// A dynamic error: the outermost message first, then the chain of causes
/// added via [`Context`].
#[derive(Debug)]
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error {
            chain: vec![m.to_string()],
        }
    }

    pub(crate) fn with_cause(outer: String, cause: String) -> Error {
        Error {
            chain: vec![outer, cause],
        }
    }

    /// The messages, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (k, m) in self.chain.iter().enumerate() {
                if k > 0 {
                    f.write_str(": ")?;
                }
                f.write_str(m)?;
            }
            Ok(())
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl std::error::Error for Error {}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to fallible results, mirroring
/// `anyhow::Context`. The underlying error is rendered (with its own
/// chain, via `{:#}`) and appended to the new error's chain.
pub trait Context<T> {
    /// Wrap the error with a fixed message.
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    /// Wrap the error with a lazily-built message.
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::with_cause(msg.to_string(), format!("{e:#}")))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.map_err(|e| Error::with_cause(f().to_string(), format!("{e:#}")))
    }
}

/// Construct an [`Error`] from a format string (the `anyhow!` stand-in).
macro_rules! format_error {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
macro_rules! bail_error {
    ($($arg:tt)*) => {
        return Err($crate::error::Error::msg(format!($($arg)*)))
    };
}

pub use bail_error as bail;
pub use format_error as anyhow;

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::io::Result<u32> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "no such file"))
    }

    #[test]
    fn display_outermost_only() {
        let e = Error::msg("boom");
        assert_eq!(format!("{e}"), "boom");
        assert_eq!(format!("{e:#}"), "boom");
    }

    #[test]
    fn context_chains_and_alternate_prints_all() {
        let r: Result<u32> = io_fail().with_context(|| "reading manifest".to_string());
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: no such file");
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["reading manifest", "no such file"]);
    }

    #[test]
    fn nested_context_flattens_into_alternate() {
        let inner: Result<u32> = io_fail().context("layer one");
        let outer = inner.context("layer two").unwrap_err();
        assert_eq!(format!("{outer:#}"), "layer two: layer one: no such file");
    }

    #[test]
    fn macros_produce_errors() {
        use crate::error::{anyhow, bail};
        let e = anyhow!("value {} bad", 7);
        assert_eq!(format!("{e}"), "value 7 bad");
        fn bails() -> Result<()> {
            bail!("nope: {}", 3)
        }
        assert_eq!(format!("{}", bails().unwrap_err()), "nope: 3");
    }

    #[test]
    fn question_mark_propagates() {
        fn inner() -> Result<u32> {
            let v: u32 = "12".parse().map_err(|e| Error::msg(format!("parse: {e}")))?;
            Ok(v)
        }
        assert_eq!(inner().unwrap(), 12);
    }
}
