//! Static analysis over COSTA plans and schedules.
//!
//! COSTA's correctness argument rests on structural invariants that the
//! engine itself never re-checks at execution time: the package matrix
//! must cover the target layout exactly once (paper §5 — every overlay
//! block has exactly one sender and one receiver), per-package volumes
//! must conserve the layout-intersection volume, send and receive
//! eligibility must agree on [`has_traffic`] (the mismatch class behind
//! the historical schedule deadlock), the relabeling σ must be a true
//! permutation, and the wire-buffer byte arithmetic must be exact. This
//! module *proves* those invariants before execution:
//!
//! * [`audit_plan`] / [`audit_batch_plan`] — the **plan auditor**: a
//!   pure, zero-dependency static checker over a built
//!   [`TransformPlan`](crate::engine::TransformPlan) /
//!   [`BatchPlan`](crate::engine::BatchPlan) producing an
//!   [`AuditReport`] whose violations name the offending ranks and
//!   blocks. The [`TransformService`](crate::service::TransformService)
//!   runs it on every plan it compiles when
//!   [`EngineConfig::audit`](crate::engine::EngineConfig::audit) is set
//!   (the default under `debug_assertions`), and the `costa audit` CLI
//!   subcommand exposes it directly.
//! * [`check_transform`] — the **delivery-order model checker**: replays
//!   the unified schedule loop on a deterministic scripted fabric
//!   ([`Fabric::run_scripted`](crate::net::Fabric::run_scripted)) under
//!   exhaustively permuted (small rank counts) or seeded-random (larger)
//!   per-receiver message-delivery orders, asserting termination, no
//!   stuck eligible-sender states, and bit-identical outputs across all
//!   interleavings.
//!
//! [`has_traffic`]: crate::comm::PackageMatrix::has_traffic

mod audit;
mod model;

pub use audit::{audit_batch_plan, audit_packages, audit_plan, AuditReport, Invariant, Violation};
pub use model::{
    check_transform, run_transform_scripted, ModelCheckConfig, ModelCheckReport,
};
