//! The delivery-order model checker: the schedule loop under every
//! message arrival order.
//!
//! Within one exchange of the unified schedule engine
//! (`engine/schedule.rs`), a rank's outgoing packages never depend on
//! data it receives — every rank posts ALL of its sends before its first
//! blocking receive. Receivers are therefore independent, and the space
//! of semantically distinct interleavings is exactly the cartesian
//! product of per-receiver arrival orders: with full traffic at
//! `nprocs = 4` that is `(3!)^4 = 1296` interleavings — tractable to
//! enumerate exhaustively. Above the configured cap the checker falls
//! back to seeded-random sampling.
//!
//! For each interleaving, [`check_transform`] replays the real
//! `execute_plan` on a [`Fabric::run_scripted`] fabric (the production
//! send/receive code paths, only the arrival order is forced) and
//! asserts:
//!
//! * **termination** — a stuck state cannot hang the checker: every run
//!   carries an exchange deadline, so a receiver waiting on traffic that
//!   can never arrive fails with an error naming the missing sender;
//! * **no stuck eligible senders** — the delivery log shows every
//!   scheduled (= eligible by `has_traffic`) envelope arrived, and
//!   nothing unscripted showed up;
//! * **bit-identical outputs** — the gathered dense result equals the
//!   first interleaving's result exactly.
//!
//! This turns the historical eligibility-mismatch deadlock class into a
//! regression test family: any schedule change that desynchronises
//! senders from receivers shows up as an `undelivered` pair or a named
//! timeout under *some* interleaving.

use std::fmt;
use std::sync::Arc;
use std::time::Duration;

use crate::engine::{execute_plan, EngineConfig, TransformJob, TransformPlan};
use crate::layout::Rank;
use crate::net::{DeliveryLog, DeliverySchedule, Fabric};
use crate::scalar::Scalar;
use crate::storage::{gather, DistMatrix};
use crate::util::Rng;

/// Model-checker knobs.
#[derive(Clone, Debug)]
pub struct ModelCheckConfig {
    /// Enumerate every interleaving when the total count is at most
    /// this; sample otherwise. Full traffic at `nprocs = 4` is 1296.
    pub max_exhaustive: usize,
    /// Seeded-random interleavings to run when above the cap.
    pub samples: usize,
    /// Seed for the sampling mode.
    pub seed: u64,
    /// Exchange deadline forced onto every run, so a genuinely stuck
    /// interleaving terminates as a named error instead of hanging the
    /// checker. Generous: it only fires on a real violation.
    pub stuck_timeout: Duration,
}

impl Default for ModelCheckConfig {
    fn default() -> Self {
        ModelCheckConfig {
            max_exhaustive: 4096,
            samples: 24,
            seed: 0xC057_A001,
            stuck_timeout: Duration::from_secs(5),
        }
    }
}

/// The model checker's verdict over all interleavings it ran.
#[derive(Clone, Debug)]
pub struct ModelCheckReport {
    pub nprocs: usize,
    /// How many delivery interleavings were executed.
    pub interleavings: usize,
    /// Whether that was the FULL interleaving space (vs. a sample).
    pub exhaustive: bool,
    pub violations: Vec<String>,
}

impl ModelCheckReport {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }
}

impl fmt::Display for ModelCheckReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mode = if self.exhaustive { "exhaustive" } else { "sampled" };
        if self.is_clean() {
            return write!(
                f,
                "model check clean: {} {mode} interleaving(s) over {} ranks, outputs bit-identical",
                self.interleavings, self.nprocs
            );
        }
        writeln!(
            f,
            "model check FAILED: {} violation(s) over {} {mode} interleaving(s):",
            self.violations.len(),
            self.interleavings
        )?;
        for v in &self.violations {
            writeln!(f, "  {v}")?;
        }
        Ok(())
    }
}

/// Deterministic source values on an exact binary-rational grid
/// (multiples of 1/64 — no NaN, no negative zero), so `==` on the
/// gathered outputs is bit-identity for every scalar type.
fn source_values<T: Scalar>(i: usize, j: usize) -> T {
    let mut z = 0x5EED_C057u64 ^ ((i as u64) << 32) ^ (j as u64);
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    T::from_f64((z % 257) as f64 * 0.015625 - 2.0)
}

/// Run ONE transform under a forced delivery schedule: deterministic
/// seeded source values, zeroed target, the production [`execute_plan`]
/// on a scripted fabric. Returns each rank's resulting shard (or its
/// error, rendered with the full context chain) plus the router's
/// delivery log. The negative tests in `tests/model_check.rs` use this
/// directly to drop an eligible sender's package and assert the timeout
/// error names it.
pub fn run_transform_scripted<T: Scalar>(
    job: &TransformJob<T>,
    cfg: &EngineConfig,
    schedule: DeliverySchedule,
) -> (Vec<Result<DistMatrix<T>, String>>, DeliveryLog) {
    let plan = Arc::new(TransformPlan::build(job, cfg));
    Fabric::run_scripted(job.nprocs(), schedule, |ctx| {
        let b = DistMatrix::generate(ctx.rank(), job.source(), source_values::<T>);
        let mut a = DistMatrix::zeros(ctx.rank(), plan.target());
        match execute_plan(ctx, &plan, job, &b, &mut a, cfg) {
            Ok(_) => Ok(a),
            Err(e) => Err(format!("{e:#}")),
        }
    })
}

/// Model-check one transform job: run it under every (or a seeded sample
/// of) per-receiver delivery order(s) and report any interleaving that
/// fails, gets stuck, leaves scheduled traffic undelivered, or produces
/// bytes that differ from the first interleaving's output.
pub fn check_transform<T: Scalar>(
    job: &TransformJob<T>,
    cfg: &EngineConfig,
    mc: &ModelCheckConfig,
) -> ModelCheckReport {
    let nprocs = job.nprocs();
    let mut exec = cfg.clone();
    if exec.exchange_timeout.is_none() {
        exec.exchange_timeout = Some(mc.stuck_timeout);
    }
    // eligible remote senders per receiver — exactly the set the
    // schedule engine's receive loop waits on
    let plan = TransformPlan::build(job, &exec);
    let incoming: Vec<Vec<Rank>> = (0..nprocs)
        .map(|dst| {
            (0..nprocs)
                .filter(|&src| src != dst && plan.packages.has_traffic(src, dst))
                .collect()
        })
        .collect();
    let total = incoming
        .iter()
        .try_fold(1u128, |acc, s| acc.checked_mul(factorial(s.len())?));
    let exhaustive = matches!(total, Some(t) if t <= mc.max_exhaustive as u128);
    let schedules = if exhaustive {
        all_orders(&incoming)
    } else {
        sampled_orders(&incoming, mc)
    };

    let mut report = ModelCheckReport {
        nprocs,
        interleavings: schedules.len(),
        exhaustive,
        violations: Vec::new(),
    };
    let mut reference: Option<Vec<T>> = None;
    for (idx, schedule) in schedules.into_iter().enumerate() {
        let desc = format!("{:?}", schedule.order);
        let (shards, log) = run_transform_scripted(job, &exec, schedule);
        if !log.is_clean() {
            report.violations.push(format!(
                "interleaving {idx} {desc}: delivery log not clean \
                 (unexpected {:?}, undelivered {:?})",
                log.unexpected, log.undelivered
            ));
            continue;
        }
        let mut ok = Vec::with_capacity(nprocs);
        let mut failed = false;
        for (rank, shard) in shards.into_iter().enumerate() {
            match shard {
                Ok(a) => ok.push(a),
                Err(e) => {
                    report
                        .violations
                        .push(format!("interleaving {idx} {desc}: rank {rank} failed: {e}"));
                    failed = true;
                }
            }
        }
        if failed {
            continue;
        }
        let dense = gather(&ok);
        match &reference {
            None => reference = Some(dense),
            Some(want) if *want == dense => {}
            Some(_) => report.violations.push(format!(
                "interleaving {idx} {desc}: output differs from interleaving 0's output"
            )),
        }
    }
    report
}

fn factorial(n: usize) -> Option<u128> {
    (1..=n as u128).try_fold(1u128, |a, b| a.checked_mul(b))
}

/// All permutations of `set`, in a deterministic order.
fn permutations(set: &[Rank]) -> Vec<Vec<Rank>> {
    if set.is_empty() {
        return vec![Vec::new()];
    }
    let mut out = Vec::new();
    for (i, &head) in set.iter().enumerate() {
        let mut rest = set.to_vec();
        rest.remove(i);
        for mut tail in permutations(&rest) {
            tail.insert(0, head);
            out.push(tail);
        }
    }
    out
}

/// The full cartesian product of per-receiver arrival orders.
fn all_orders(incoming: &[Vec<Rank>]) -> Vec<DeliverySchedule> {
    let perms: Vec<Vec<Vec<Rank>>> = incoming.iter().map(|s| permutations(s)).collect();
    let mut out = Vec::new();
    let mut idx = vec![0usize; perms.len()];
    loop {
        out.push(DeliverySchedule::new(
            idx.iter().zip(&perms).map(|(&i, p)| p[i].clone()).collect(),
        ));
        let mut d = 0;
        loop {
            if d == perms.len() {
                return out;
            }
            idx[d] += 1;
            if idx[d] < perms[d].len() {
                break;
            }
            idx[d] = 0;
            d += 1;
        }
    }
}

/// `mc.samples` independent seeded-random arrival orders.
fn sampled_orders(incoming: &[Vec<Rank>], mc: &ModelCheckConfig) -> Vec<DeliverySchedule> {
    let mut rng = Rng::new(mc.seed);
    (0..mc.samples)
        .map(|_| {
            DeliverySchedule::new(
                incoming
                    .iter()
                    .map(|srcs| {
                        let p = rng.permutation(srcs.len());
                        p.into_iter().map(|k| srcs[k]).collect()
                    })
                    .collect(),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::{block_cyclic, GridOrder, Op};

    #[test]
    fn permutations_cover_the_space() {
        assert_eq!(permutations(&[]).len(), 1);
        assert_eq!(permutations(&[7]).len(), 1);
        let p3 = permutations(&[0, 1, 2]);
        assert_eq!(p3.len(), 6);
        let mut uniq = p3.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), 6, "all distinct");
    }

    #[test]
    fn two_rank_exchange_is_clean_under_all_orders() {
        let lb = block_cyclic(8, 8, 4, 4, 2, 1, GridOrder::RowMajor, 2);
        let la = block_cyclic(8, 8, 4, 4, 1, 2, GridOrder::RowMajor, 2);
        let job = TransformJob::<f32>::new(lb, la, Op::Identity);
        let r = check_transform(&job, &EngineConfig::default(), &ModelCheckConfig::default());
        assert!(r.exhaustive);
        assert!(r.is_clean(), "{r}");
        assert!(r.interleavings >= 1);
    }
}
