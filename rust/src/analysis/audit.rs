//! The plan auditor: a pure static checker over built plans.
//!
//! Every check recomputes its invariant from first principles — the
//! auditor never calls the arithmetic it is auditing. Volumes are summed
//! with overflow-checked `u64` ops directly from the transfer ranges
//! (never through [`BlockXfer::volume`], which panics on overflow), so a
//! corrupt plan is *reported*, not crashed on.
//!
//! The invariants, in the order they are checked:
//!
//! 1. **Structure** — the job's [`Selection`] fits the two layouts (for
//!    the dense identity selection this reduces to `op(B)` shape = `A`
//!    shape), the package matrix covers the right process count, every
//!    transfer rectangle lies inside the target matrix, and every
//!    recorded source rectangle lies inside op(B) with the same
//!    dimensions as its target rectangle.
//! 2. **RelabelBijectivity** — σ is a true permutation of `0..nprocs`.
//! 3. **EligibilitySymmetry** — sender and receiver eligibility both key
//!    on [`PackageMatrix::has_traffic`] (= the cell is non-empty), so a
//!    non-empty cell whose total volume is zero (or any zero-volume
//!    rectangle) desynchronises the two sides: the receiver waits for a
//!    package carrying nothing. This is the historical deadlock class.
//! 4. **Coverage** — selection-aware cell counts: every SELECTED target
//!    cell is written by exactly one rectangle across ALL packages, and
//!    every unselected cell by none (for the dense selection: every
//!    target cell exactly once — no gaps, no double writes). An
//!    extraction or assignment plan therefore never false-positives on
//!    "uncovered" cells outside its window.
//! 5. **VolumeConservation** — per-(src, dst) rectangle-volume sums
//!    equal an independently-computed expectation (the layout
//!    intersection [`VolumeMatrix::from_layouts`] for dense plans; a
//!    per-element owner walk over the selection's index maps otherwise),
//!    the grand total equals the selected cell count `k·l`, and the
//!    plan's recorded `achieved_remote_volume` matches.
//! 6. **ByteAccounting** — the wire-buffer size arithmetic
//!    (`elements × size_of::<T>()`, prefix offsets) is exact in `usize`
//!    for every package, mirroring `engine/packing.rs`.
//!
//! [`Selection`]: crate::layout::Selection

use std::fmt;

use crate::comm::{PackageMatrix, VolumeMatrix};
use crate::engine::{BatchPlan, TransformJob, TransformPlan};
use crate::layout::{IndexVec, Layout, Op, Selection};
use crate::scalar::Scalar;
use crate::util::is_permutation;

/// Which structural invariant a [`Violation`] breaks.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Invariant {
    /// Shapes/process counts/bounds are inconsistent.
    Structure,
    /// σ is not a permutation of `0..nprocs`.
    RelabelBijectivity,
    /// A package is eligible (non-empty) but moves zero elements — the
    /// sender/receiver `has_traffic` contract is broken.
    EligibilitySymmetry,
    /// A target cell is written by zero or by more than one rectangle.
    Coverage,
    /// Package volumes do not conserve the layout-intersection volume
    /// (or overflow u64).
    VolumeConservation,
    /// Wire-buffer byte sizes/offsets overflow or disagree with the
    /// packing arithmetic.
    ByteAccounting,
}

impl Invariant {
    pub fn name(self) -> &'static str {
        match self {
            Invariant::Structure => "structure",
            Invariant::RelabelBijectivity => "relabel-bijectivity",
            Invariant::EligibilitySymmetry => "eligibility-symmetry",
            Invariant::Coverage => "coverage",
            Invariant::VolumeConservation => "volume-conservation",
            Invariant::ByteAccounting => "byte-accounting",
        }
    }
}

impl fmt::Display for Invariant {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One broken invariant, with a detail string naming the ranks, blocks
/// or cells involved.
#[derive(Clone, Debug)]
pub struct Violation {
    pub invariant: Invariant,
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}", self.invariant, self.detail)
    }
}

/// The auditor's verdict: every violation found, or none.
#[derive(Clone, Debug, Default)]
pub struct AuditReport {
    /// Process count of the audited plan.
    pub nprocs: usize,
    /// Number of batch members audited (1 for a single plan).
    pub members: usize,
    /// Total transfer rectangles inspected.
    pub rects_checked: usize,
    pub violations: Vec<Violation>,
}

impl AuditReport {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Violations of one specific invariant (test helper).
    pub fn of(&self, inv: Invariant) -> impl Iterator<Item = &Violation> {
        self.violations.iter().filter(move |v| v.invariant == inv)
    }

    /// Whether any violation of `inv` was recorded.
    pub fn breaks(&self, inv: Invariant) -> bool {
        self.of(inv).next().is_some()
    }

    fn push(&mut self, invariant: Invariant, detail: String) {
        self.violations.push(Violation { invariant, detail });
    }
}

impl fmt::Display for AuditReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_clean() {
            return write!(
                f,
                "audit clean: {} member(s), {} ranks, {} transfer rectangles",
                self.members, self.nprocs, self.rects_checked
            );
        }
        writeln!(
            f,
            "audit FAILED: {} violation(s) over {} member(s), {} ranks:",
            self.violations.len(),
            self.members,
            self.nprocs
        )?;
        for v in &self.violations {
            writeln!(f, "  {v}")?;
        }
        Ok(())
    }
}

/// Coverage strategy cutoff: below this many target cells the auditor
/// paints an exact per-cell write-count array; above it, the banded
/// interval-tiling check is used (exact too, but reports ranges rather
/// than single cells).
const PAINT_LIMIT: usize = 1 << 24;

/// Cap on how many violations one coverage/conservation pass reports, so
/// a badly corrupt plan yields a readable report instead of megabytes.
const MAX_DETAILS: usize = 8;

/// Audit a single-job plan against the job that built it.
///
/// Pure and read-only; returns every violation found (an empty report
/// means the plan is provably well-formed). Runs automatically on every
/// service-compiled plan when [`EngineConfig::audit`] is set.
///
/// [`EngineConfig::audit`]: crate::engine::EngineConfig::audit
pub fn audit_plan<T: Scalar>(plan: &TransformPlan, job: &TransformJob<T>) -> AuditReport {
    let mut r = AuditReport {
        nprocs: job.nprocs(),
        members: 1,
        ..AuditReport::default()
    };
    let sigma_ok = check_sigma(&plan.relabeling.sigma, job.nprocs(), &mut r);
    if sigma_ok {
        let want = if plan.relabeling.is_identity() {
            job.target()
        } else {
            std::sync::Arc::new(job.target().permuted(&plan.relabeling.sigma))
        };
        if *plan.target != *want {
            r.push(
                Invariant::Structure,
                "plan target layout is not the job target permuted by sigma".into(),
            );
        }
    }
    audit_packages(
        &plan.target,
        &job.source(),
        job.op(),
        job.selection(),
        &plan.packages,
        std::mem::size_of::<T>(),
        &mut r,
    );
    let achieved = checked_remote_volume(&plan.packages);
    match achieved {
        Some(v) if v == plan.achieved_remote_volume => {}
        Some(v) => r.push(
            Invariant::VolumeConservation,
            format!(
                "plan records achieved_remote_volume = {}, packages actually move {v} remote elements",
                plan.achieved_remote_volume
            ),
        ),
        // overflow already reported per-cell by audit_packages
        None => {}
    }
    r
}

/// Audit a batch plan against the jobs that built it: σ bijectivity
/// once, then every member's packages against its own (permuted) target.
pub fn audit_batch_plan<T: Scalar>(plan: &BatchPlan, jobs: &[TransformJob<T>]) -> AuditReport {
    let nprocs = jobs.first().map(|j| j.nprocs()).unwrap_or(0);
    let mut r = AuditReport {
        nprocs,
        members: jobs.len(),
        ..AuditReport::default()
    };
    if plan.targets.len() != jobs.len() || plan.packages.len() != jobs.len() {
        r.push(
            Invariant::Structure,
            format!(
                "batch plan covers {} target(s) / {} package matrix(es) for {} job(s)",
                plan.targets.len(),
                plan.packages.len(),
                jobs.len()
            ),
        );
        return r;
    }
    let sigma_ok = check_sigma(&plan.relabeling.sigma, nprocs, &mut r);
    let mut remote_sum: Option<u64> = Some(0);
    for (i, job) in jobs.iter().enumerate() {
        if sigma_ok {
            let want = if plan.relabeling.is_identity() {
                job.target()
            } else {
                std::sync::Arc::new(job.target().permuted(&plan.relabeling.sigma))
            };
            if *plan.targets[i] != *want {
                r.push(
                    Invariant::Structure,
                    format!("batch member {i}: target layout is not the job target permuted by sigma"),
                );
            }
        }
        let before = r.violations.len();
        audit_packages(
            &plan.targets[i],
            &job.source(),
            job.op(),
            job.selection(),
            &plan.packages[i],
            std::mem::size_of::<T>(),
            &mut r,
        );
        for v in &mut r.violations[before..] {
            v.detail = format!("batch member {i}: {}", v.detail);
        }
        remote_sum = remote_sum
            .zip(checked_remote_volume(&plan.packages[i]))
            .and_then(|(a, b)| a.checked_add(b));
    }
    match remote_sum {
        Some(v) if v == plan.achieved_remote_volume => {}
        Some(v) => r.push(
            Invariant::VolumeConservation,
            format!(
                "batch plan records achieved_remote_volume = {}, members actually move {v} remote elements",
                plan.achieved_remote_volume
            ),
        ),
        None => {}
    }
    r
}

/// Audit one package matrix against the (target, source, op, selection)
/// quadruple it was built from. This is the core the plan/batch entry
/// points share; it is public so tools can audit raw [`packages_for`] /
/// [`packages_for_selection`] output without a full plan. Dense plans
/// pass [`Selection::dense`].
///
/// [`packages_for`]: crate::comm::packages_for
/// [`packages_for_selection`]: crate::comm::packages_for_selection
pub fn audit_packages(
    target: &Layout,
    source: &Layout,
    op: Op,
    sel: &Selection,
    packages: &PackageMatrix,
    elem_size: usize,
    r: &mut AuditReport,
) {
    let (m, n) = target.shape();
    let (cm, cn) = op.out_shape(source.shape());
    let nprocs = target.nprocs;
    if let Err(e) = sel.validate((cm, cn), (m, n)) {
        r.push(
            Invariant::Structure,
            format!(
                "selection does not fit op(B) shape {:?} / A shape {:?}: {e}",
                (cm, cn),
                (m, n)
            ),
        );
        return;
    }
    if source.nprocs != nprocs || packages.nprocs() != nprocs {
        r.push(
            Invariant::Structure,
            format!(
                "process counts disagree: target {nprocs}, source {}, package matrix {}",
                source.nprocs,
                packages.nprocs()
            ),
        );
        return;
    }

    // ---- per-cell walk: bounds, zero-volume entries, checked volumes --
    // expected volumes are recomputed independently of the package
    // builder: the closed-form layout intersection for dense plans, a
    // per-element owner walk over the index maps for selections (skipped
    // above PAINT_LIMIT selected cells; the grand total below still pins
    // the sum)
    let dense = sel.is_dense();
    let expected: Option<VolumeMatrix> = if dense {
        Some(VolumeMatrix::from_layouts(target, source, op))
    } else if sel.selected_cells() <= PAINT_LIMIT as u64 {
        Some(expected_selection_volumes(target, source, op, sel))
    } else {
        None
    };
    let mut structure_seen = 0usize;
    let mut painted: Vec<Painted> = Vec::new();
    let mut grand_total: Option<u64> = Some(0);
    for src in 0..nprocs {
        for dst in 0..nprocs {
            let cell = packages.get(src, dst);
            let mut cell_volume: Option<u64> = Some(0);
            for x in cell {
                r.rects_checked += 1;
                let rows = x.rows.clone();
                let cols = x.cols.clone();
                let degenerate = rows.start >= rows.end || cols.start >= cols.end;
                if degenerate {
                    r.push(
                        Invariant::EligibilitySymmetry,
                        format!(
                            "package {src} -> {dst} carries a zero-volume rectangle rows {rows:?} cols {cols:?}; \
                             has_traffic would report an exchange that moves nothing"
                        ),
                    );
                    continue;
                }
                let mut in_bounds = rows.end <= m && cols.end <= n;
                if let Some(s) = &x.src {
                    // a recorded source rectangle must be a pure
                    // translation of the target rectangle inside op(B)
                    if s.rows.end - s.rows.start != rows.end - rows.start
                        || s.cols.end - s.cols.start != cols.end - cols.start
                    {
                        if structure_seen < MAX_DETAILS {
                            r.push(
                                Invariant::Structure,
                                format!(
                                    "package {src} -> {dst}: source rectangle rows {:?} cols {:?} \
                                     does not match its target rectangle rows {rows:?} cols {cols:?}",
                                    s.rows, s.cols
                                ),
                            );
                        }
                        structure_seen += 1;
                        in_bounds = false;
                    } else if s.rows.end > cm || s.cols.end > cn {
                        if structure_seen < MAX_DETAILS {
                            r.push(
                                Invariant::Structure,
                                format!(
                                    "package {src} -> {dst}: source rectangle rows {:?} cols {:?} \
                                     exceeds the {cm} x {cn} op(B)",
                                    s.rows, s.cols
                                ),
                            );
                        }
                        structure_seen += 1;
                        in_bounds = false;
                    }
                }
                if !in_bounds {
                    if rows.end > m || cols.end > n {
                        if structure_seen < MAX_DETAILS {
                            r.push(
                                Invariant::Structure,
                                format!(
                                    "package {src} -> {dst}: rectangle rows {rows:?} cols {cols:?} \
                                     exceeds the {m} x {n} target"
                                ),
                            );
                        }
                        structure_seen += 1;
                    }
                } else {
                    painted.push(Painted {
                        rows: (rows.start, rows.end),
                        cols: (cols.start, cols.end),
                        src,
                        dst,
                    });
                }
                // checked volume straight from the ranges — never through
                // BlockXfer::volume(), which panics on overflow
                let vol = ((rows.end - rows.start) as u64)
                    .checked_mul((cols.end - cols.start) as u64);
                if vol.is_none() {
                    r.push(
                        Invariant::VolumeConservation,
                        format!(
                            "package {src} -> {dst}: rectangle rows {rows:?} cols {cols:?} \
                             volume overflows u64"
                        ),
                    );
                }
                cell_volume = cell_volume.zip(vol).and_then(|(a, b)| a.checked_add(b));
            }
            match cell_volume {
                None => {
                    grand_total = None;
                    r.push(
                        Invariant::VolumeConservation,
                        format!("package {src} -> {dst}: summed volume overflows u64"),
                    );
                }
                Some(v) => {
                    grand_total = grand_total.and_then(|t| t.checked_add(v));
                    if let Some(exp) = &expected {
                        let want = exp.get(src, dst);
                        if v != want {
                            r.push(
                                Invariant::VolumeConservation,
                                format!(
                                    "package {src} -> {dst} moves {v} elements, \
                                     the selection's owner walk requires {want}"
                                ),
                            );
                        }
                    }
                    if packages.has_traffic(src, dst) && v == 0 {
                        r.push(
                            Invariant::EligibilitySymmetry,
                            format!(
                                "package {src} -> {dst} is eligible (has_traffic) but moves \
                                 zero elements: the receiver would wait for an empty exchange"
                            ),
                        );
                    }
                }
            }
            // ---- byte accounting: mirror the packing arithmetic --------
            check_bytes(cell, src, dst, elem_size, r);
        }
    }
    if structure_seen > MAX_DETAILS {
        r.push(
            Invariant::Structure,
            format!("...and {} more malformed rectangles", structure_seen - MAX_DETAILS),
        );
    }
    // the grand total must equal the selected cell count k*l (= m*n for
    // the dense selection) — this holds even when the per-pair expected
    // walk was skipped for being too large
    if let Some(total) = grand_total {
        if total != sel.selected_cells() {
            r.push(
                Invariant::VolumeConservation,
                format!(
                    "packages move {total} elements in total, the selection covers {} cells",
                    sel.selected_cells()
                ),
            );
        }
    }

    // ---- coverage: every SELECTED target cell written exactly once ----
    if let Some(total_cells) = m.checked_mul(n) {
        if total_cells <= PAINT_LIMIT {
            let (row_sel, col_sel) = (axis_mask(&sel.dst_rows, m), axis_mask(&sel.dst_cols, n));
            paint_coverage(m, n, &painted, &row_sel, &col_sel, r);
        } else if dense {
            banded_coverage(m, n, &painted, r);
        }
        // non-dense above the paint limit: the banded tiling argument
        // does not apply to sparse windows, so exact per-cell coverage
        // is skipped there; the selected-volume total above still pins
        // the sum
    }
}

/// Which indices of a target axis the selection writes. Identity maps
/// span the whole axis (their extent is validated upstream).
fn axis_mask(v: &IndexVec, extent: usize) -> Vec<bool> {
    match v.as_map() {
        None => vec![true; extent],
        Some(map) => {
            let mut mask = vec![false; extent];
            for &i in map {
                if i < extent {
                    mask[i] = true;
                }
            }
            mask
        }
    }
}

/// Expected per-(src, dst) volumes for a selection, recomputed from
/// first principles: walk every logical cell, resolve its source owner
/// through the source maps (transposed into B space for op ∈ {T, C})
/// and its destination owner through the target maps, and count. Never
/// touches the run decomposition the package builder uses.
fn expected_selection_volumes(
    target: &Layout,
    source: &Layout,
    op: Op,
    sel: &Selection,
) -> VolumeMatrix {
    let nprocs = target.nprocs;
    let mut v = VolumeMatrix::zeros(nprocs);
    let (k, l) = sel.logical_shape();
    for i in 0..k {
        let sr = sel.src_rows.get(i);
        let dr = sel.dst_rows.get(i);
        for j in 0..l {
            let sc = sel.src_cols.get(j);
            let dc = sel.dst_cols.get(j);
            let (br, bc) = if op.is_transposed() { (sc, sr) } else { (sr, sc) };
            let s = source.owner_of_element(br, bc);
            let d = target.owner_of_element(dr, dc);
            v.add(s, d, 1);
        }
    }
    v
}

/// One in-bounds, non-degenerate rectangle tagged with its package.
struct Painted {
    rows: (usize, usize),
    cols: (usize, usize),
    src: usize,
    dst: usize,
}

fn check_sigma(sigma: &[usize], nprocs: usize, r: &mut AuditReport) -> bool {
    if sigma.len() != nprocs {
        r.push(
            Invariant::RelabelBijectivity,
            format!("sigma covers {} ranks, plan has {nprocs}", sigma.len()),
        );
        return false;
    }
    if !is_permutation(sigma) {
        // name a concrete witness: the first rank hit twice or out of range
        let mut seen = vec![false; nprocs];
        let mut witness = String::new();
        for (i, &s) in sigma.iter().enumerate() {
            if s >= nprocs {
                witness = format!("sigma[{i}] = {s} is out of range");
                break;
            }
            if seen[s] {
                witness = format!("rank {s} is the image of two ranks (second: sigma[{i}])");
                break;
            }
            seen[s] = true;
        }
        r.push(
            Invariant::RelabelBijectivity,
            format!("sigma is not a permutation of 0..{nprocs}: {witness}"),
        );
        return false;
    }
    true
}

/// Exact per-cell coverage: paint saturating write counts, then report
/// selected cells not written exactly once — and unselected cells
/// written at all (naming the covering packages). The masks carry which
/// target rows/columns the selection writes; dense plans pass all-true
/// masks and recover the historical "every cell exactly once" check.
fn paint_coverage(
    m: usize,
    n: usize,
    rects: &[Painted],
    row_sel: &[bool],
    col_sel: &[bool],
    r: &mut AuditReport,
) {
    let mut paint = vec![0u8; m * n];
    for p in rects {
        for i in p.rows.0..p.rows.1 {
            let row = &mut paint[i * n..(i + 1) * n];
            for c in &mut row[p.cols.0..p.cols.1] {
                *c = c.saturating_add(1);
            }
        }
    }
    let mut uncovered = 0usize;
    let mut multiple = 0usize;
    let mut unselected = 0usize;
    for i in 0..m {
        for j in 0..n {
            let selected = row_sel[i] && col_sel[j];
            let count = paint[i * n + j];
            if !selected {
                if count != 0 {
                    if unselected < MAX_DETAILS {
                        r.push(
                            Invariant::Coverage,
                            format!(
                                "unselected target cell ({i}, {j}) is written by {count} transfer(s)"
                            ),
                        );
                    }
                    unselected += 1;
                }
                continue;
            }
            match count {
                1 => {}
                0 => {
                    if uncovered < MAX_DETAILS {
                        r.push(
                            Invariant::Coverage,
                            format!("target cell ({i}, {j}) is written by no transfer"),
                        );
                    }
                    uncovered += 1;
                }
                k => {
                    if multiple < MAX_DETAILS {
                        let covers: Vec<String> = rects
                            .iter()
                            .filter(|p| {
                                (p.rows.0..p.rows.1).contains(&i) && (p.cols.0..p.cols.1).contains(&j)
                            })
                            .map(|p| {
                                format!(
                                    "{} -> {} rows {}..{} cols {}..{}",
                                    p.src, p.dst, p.rows.0, p.rows.1, p.cols.0, p.cols.1
                                )
                            })
                            .collect();
                        r.push(
                            Invariant::Coverage,
                            format!(
                                "target cell ({i}, {j}) is written by {k} transfers: {}",
                                covers.join("; ")
                            ),
                        );
                    }
                    multiple += 1;
                }
            }
        }
    }
    if uncovered > MAX_DETAILS {
        r.push(
            Invariant::Coverage,
            format!("...and {} more uncovered cells", uncovered - MAX_DETAILS),
        );
    }
    if multiple > MAX_DETAILS {
        r.push(
            Invariant::Coverage,
            format!("...and {} more multiply-written cells", multiple - MAX_DETAILS),
        );
    }
    if unselected > MAX_DETAILS {
        r.push(
            Invariant::Coverage,
            format!(
                "...and {} more unselected-but-written cells",
                unselected - MAX_DETAILS
            ),
        );
    }
}

/// Coverage for layouts too large to paint: overlay rectangles come from
/// a grid overlay, so the distinct row ranges must tile `[0, m)` exactly
/// and, within each row band, the column ranges must tile `[0, n)`.
/// Exact for any rectangle set (a gap, overlap, or inconsistent band is
/// reported by range), just coarser-grained in its messages.
fn banded_coverage(m: usize, n: usize, rects: &[Painted], r: &mut AuditReport) {
    use std::collections::BTreeMap;
    let mut bands: BTreeMap<(usize, usize), Vec<(usize, usize, usize, usize)>> = BTreeMap::new();
    for p in rects {
        bands
            .entry(p.rows)
            .or_default()
            .push((p.cols.0, p.cols.1, p.src, p.dst));
    }
    // distinct row ranges must tile [0, m)
    let mut at = 0usize;
    for &(s, e) in bands.keys() {
        if s != at {
            r.push(
                Invariant::Coverage,
                if s > at {
                    format!("target rows {at}..{s} are written by no transfer")
                } else {
                    format!("row band {s}..{e} overlaps the previous band ending at {at}")
                },
            );
        }
        at = at.max(e);
    }
    if at != m {
        r.push(
            Invariant::Coverage,
            format!("target rows {at}..{m} are written by no transfer"),
        );
    }
    // within each band, column ranges must tile [0, n)
    for ((rs, re), mut cols) in bands {
        cols.sort_unstable();
        let mut at = 0usize;
        for &(s, e, src, dst) in &cols {
            if s != at {
                r.push(
                    Invariant::Coverage,
                    if s > at {
                        format!("rows {rs}..{re}: cols {at}..{s} are written by no transfer")
                    } else {
                        format!(
                            "rows {rs}..{re}: cols {s}..{e} (package {src} -> {dst}) \
                             overlap the previous rectangle ending at {at}"
                        )
                    },
                );
            }
            at = at.max(e);
        }
        if at != n {
            r.push(
                Invariant::Coverage,
                format!("rows {rs}..{re}: cols {at}..{n} are written by no transfer"),
            );
        }
    }
}

/// Byte accounting for one package: element counts, the
/// `elements × elem_size` wire-buffer size, and the running prefix
/// offsets must all be exact in `usize` — the same arithmetic
/// `engine/packing.rs` performs when building and validating wire
/// buffers.
fn check_bytes(
    cell: &[crate::comm::BlockXfer],
    src: usize,
    dst: usize,
    elem_size: usize,
    r: &mut AuditReport,
) {
    let mut elems: usize = 0;
    for x in cell {
        let h = x.rows.end.saturating_sub(x.rows.start) as u64;
        let w = x.cols.end.saturating_sub(x.cols.start) as u64;
        let vol = match h.checked_mul(w).and_then(|v| usize::try_from(v).ok()) {
            Some(v) => v,
            None => {
                r.push(
                    Invariant::ByteAccounting,
                    format!(
                        "package {src} -> {dst}: rectangle rows {:?} cols {:?} element count \
                         does not fit in usize",
                        x.rows, x.cols
                    ),
                );
                return;
            }
        };
        // the prefix offset every unpack of this package will compute
        elems = match elems.checked_add(vol) {
            Some(e) => e,
            None => {
                r.push(
                    Invariant::ByteAccounting,
                    format!("package {src} -> {dst}: payload element prefix overflows usize"),
                );
                return;
            }
        };
    }
    if elems.checked_mul(elem_size).is_none() {
        r.push(
            Invariant::ByteAccounting,
            format!(
                "package {src} -> {dst}: wire-buffer size {elems} elements x {elem_size} bytes \
                 overflows usize"
            ),
        );
    }
}

/// `PackageMatrix::remote_volume` recomputed with checked arithmetic
/// straight from the ranges; `None` on overflow (already reported
/// per-cell by the caller).
fn checked_remote_volume(p: &PackageMatrix) -> Option<u64> {
    let n = p.nprocs();
    let mut total: u64 = 0;
    for src in 0..n {
        for dst in 0..n {
            if src == dst {
                continue;
            }
            for x in p.get(src, dst) {
                let h = (x.rows.end.saturating_sub(x.rows.start)) as u64;
                let w = (x.cols.end.saturating_sub(x.cols.start)) as u64;
                total = total.checked_add(h.checked_mul(w)?)?;
            }
        }
    }
    Some(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::Solver;
    use crate::engine::EngineConfig;
    use crate::layout::{block_cyclic, GridOrder};

    fn job() -> TransformJob<f32> {
        let lb = block_cyclic(24, 20, 3, 7, 2, 2, GridOrder::ColMajor, 4);
        let la = block_cyclic(24, 20, 5, 4, 2, 2, GridOrder::RowMajor, 4);
        TransformJob::new(lb, la, Op::Identity)
    }

    #[test]
    fn built_plan_audits_clean() {
        let j = job();
        let plan = TransformPlan::build(&j, &EngineConfig::default());
        let r = audit_plan(&plan, &j);
        assert!(r.is_clean(), "{r}");
        assert!(r.rects_checked > 0);
    }

    #[test]
    fn relabeled_plan_audits_clean() {
        let j = job();
        let plan = TransformPlan::build(&j, &EngineConfig::default().with_relabel(Solver::Hungarian));
        let r = audit_plan(&plan, &j);
        assert!(r.is_clean(), "{r}");
    }

    #[test]
    fn batch_plan_audits_clean() {
        let jobs = vec![job(), job().alpha(0.5).beta(2.0)];
        let plan = BatchPlan::build(&jobs, &EngineConfig::default().with_relabel(Solver::Hungarian));
        let r = audit_batch_plan(&plan, &jobs);
        assert!(r.is_clean(), "{r}");
        assert_eq!(r.members, 2);
    }

    #[test]
    fn dropped_transfer_breaks_coverage() {
        let j = job();
        let mut plan = TransformPlan::build(&j, &EngineConfig::default());
        let (src, dst) = first_remote_cell(&plan.packages);
        plan.packages.cell_mut(src, dst).pop();
        let r = audit_plan(&plan, &j);
        assert!(r.breaks(Invariant::Coverage), "{r}");
        assert!(r.breaks(Invariant::VolumeConservation), "{r}");
    }

    #[test]
    fn non_bijective_sigma_is_named() {
        let j = job();
        let mut plan = TransformPlan::build(&j, &EngineConfig::default());
        plan.relabeling.sigma = vec![0, 1, 1, 3];
        let r = audit_plan(&plan, &j);
        assert!(r.breaks(Invariant::RelabelBijectivity), "{r}");
        let v = r.of(Invariant::RelabelBijectivity).next().unwrap();
        assert!(v.detail.contains("rank 1"), "{v}");
    }

    #[test]
    fn permute_plan_audits_clean() {
        let lb = block_cyclic(24, 20, 3, 7, 2, 2, GridOrder::ColMajor, 4);
        let la = block_cyclic(24, 20, 5, 4, 2, 2, GridOrder::RowMajor, 4);
        let rows: Vec<usize> = (0..24).map(|i| (i + 11) % 24).collect();
        let cols: Vec<usize> = (0..20).rev().collect();
        let j = TransformJob::<f32>::permute(lb, la, Op::Identity, rows, cols);
        let hungarian = EngineConfig::default().with_relabel(Solver::Hungarian);
        for cfg in [EngineConfig::default(), hungarian] {
            let plan = TransformPlan::build(&j, &cfg);
            let r = audit_plan(&plan, &j);
            assert!(r.is_clean(), "{r}");
            assert!(r.rects_checked > 0);
        }
    }

    #[test]
    fn extraction_plan_audits_clean() {
        // regression: the coverage invariant must count only the selected
        // window, not report every unselected target cell as uncovered
        let lb = block_cyclic(24, 20, 3, 7, 2, 2, GridOrder::ColMajor, 4);
        let la = block_cyclic(9, 6, 5, 4, 2, 2, GridOrder::RowMajor, 4);
        let rows: Vec<usize> = (4..13).collect();
        let cols: Vec<usize> = vec![0, 3, 7, 8, 15, 19];
        let j = TransformJob::<f32>::extract(lb, la, Op::Identity, rows, cols);
        let plan = TransformPlan::build(&j, &EngineConfig::default());
        let r = audit_plan(&plan, &j);
        assert!(r.is_clean(), "{r}");
    }

    #[test]
    fn assignment_plan_audits_clean() {
        let lb = block_cyclic(9, 6, 3, 7, 2, 2, GridOrder::ColMajor, 4);
        let la = block_cyclic(24, 20, 5, 4, 2, 2, GridOrder::RowMajor, 4);
        let rows: Vec<usize> = (4..13).collect();
        let cols: Vec<usize> = vec![0, 3, 7, 8, 15, 19];
        let j = TransformJob::<f32>::assign(lb, la, Op::Identity, rows, cols);
        let plan = TransformPlan::build(&j, &EngineConfig::default());
        let r = audit_plan(&plan, &j);
        assert!(r.is_clean(), "{r}");
    }

    #[test]
    fn dropped_selection_transfer_breaks_coverage() {
        let lb = block_cyclic(24, 20, 3, 7, 2, 2, GridOrder::ColMajor, 4);
        let la = block_cyclic(24, 20, 5, 4, 2, 2, GridOrder::RowMajor, 4);
        let rows: Vec<usize> = (0..24).map(|i| (i + 11) % 24).collect();
        let cols: Vec<usize> = (0..20).collect();
        let j = TransformJob::<f32>::permute(lb, la, Op::Identity, rows, cols);
        let mut plan = TransformPlan::build(&j, &EngineConfig::default());
        let (src, dst) = first_remote_cell(&plan.packages);
        plan.packages.cell_mut(src, dst).pop();
        let r = audit_plan(&plan, &j);
        assert!(r.breaks(Invariant::Coverage), "{r}");
        assert!(r.breaks(Invariant::VolumeConservation), "{r}");
    }

    fn first_remote_cell(p: &PackageMatrix) -> (usize, usize) {
        for s in 0..p.nprocs() {
            for d in 0..p.nprocs() {
                if s != d && p.has_traffic(s, d) {
                    return (s, d);
                }
            }
        }
        panic!("no remote traffic")
    }
}
