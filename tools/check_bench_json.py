#!/usr/bin/env python3
"""Schema, invariant, and regression check for BENCH_server.json.

The `server_throughput` bench overwrites BENCH_server.json at the repo
root on every run; the committed copy is the perf-trajectory seed. This
check keeps the schema STABLE across regenerations so downstream
tooling (perf dashboards, regression diffs) never silently breaks:

* top level carries exactly `bench`, `fixture`, `cases` (plus an
  optional `provenance` string the seed uses to mark unmeasured data);
* the fixture keys and every case's keys match the bench writer
  byte-for-byte — a key added to the writer must be added HERE too;
* the derived columns are self-consistent: `requests_per_sec` agrees
  with `requests / wall_secs`, `coalesce_factor` with
  `requests / rounds`, `rounds <= requests`, and `p50 <= p99`.

Two modes:

    check_bench_json.py
        Schema-check the committed BENCH_server.json at the repo root.

    check_bench_json.py --compare OLD.json NEW.json
        Schema-check both files, match cases by
        (mode, coalesce_window_us, clients), print per-key deltas, and
        exit nonzero if any case's `requests_per_sec` regressed by more
        than 20% — UNLESS the old file carries a `provenance` key,
        which marks its numbers as an unmeasured placeholder seed: then
        the deltas are informational and the gate stays disarmed (the
        gate arms automatically once a measured baseline — which the
        bench writer emits without `provenance` — is committed).

Exits nonzero listing every violation.
"""

import argparse
import json
import sys
from pathlib import Path

FIXTURE_KEYS = {"ranks", "m", "src_block", "dst_block", "scalar"}
CASE_KEYS = {
    "mode",
    "coalesce_window_us",
    "clients",
    "requests",
    "wall_secs",
    "requests_per_sec",
    "rounds",
    "coalesce_factor",
    "p50_latency_secs",
    "p99_latency_secs",
}
MODES = {"spawn-per-transform", "resident", "epoch-shuffle"}

# requests_per_sec below 80% of the baseline fails the compare gate
REGRESSION_FLOOR = 0.8


def close(a: float, b: float, rel: float = 0.02, absolute: float = 0.02) -> bool:
    return abs(a - b) <= absolute + rel * max(abs(a), abs(b))


def load(path: Path):
    try:
        return json.loads(path.read_text(encoding="utf-8")), None
    except (OSError, ValueError) as e:
        return None, f"{path}: unreadable or invalid JSON: {e}"


def check_doc(doc, label: str) -> list:
    """All schema and self-consistency violations in one parsed doc."""
    errors = []
    top = set(doc)
    if not {"bench", "fixture", "cases"} <= top:
        errors.append(f"{label}: top-level keys {sorted(top)} must include bench, fixture, cases")
    if extra := top - {"bench", "fixture", "cases", "provenance"}:
        errors.append(f"{label}: unexpected top-level keys {sorted(extra)} — schema drift")
    if doc.get("bench") != "server_throughput":
        errors.append(f"{label}: bench is {doc.get('bench')!r}, expected 'server_throughput'")

    fixture = doc.get("fixture", {})
    if set(fixture) != FIXTURE_KEYS:
        errors.append(f"{label}: fixture keys {sorted(fixture)} != {sorted(FIXTURE_KEYS)}")

    cases = doc.get("cases", [])
    if not cases:
        errors.append(f"{label}: cases is empty")
    for i, case in enumerate(cases):
        where = f"{label}: cases[{i}]"
        if set(case) != CASE_KEYS:
            errors.append(f"{where}: keys {sorted(case)} != {sorted(CASE_KEYS)}")
            continue
        if case["mode"] not in MODES:
            errors.append(f"{where}: mode {case['mode']!r} not in {sorted(MODES)}")
        for key in CASE_KEYS - {"mode"}:
            if not isinstance(case[key], (int, float)) or isinstance(case[key], bool):
                errors.append(f"{where}: {key} is {type(case[key]).__name__}, expected number")
        if any(not isinstance(case[k], (int, float)) for k in CASE_KEYS - {"mode"}):
            continue
        if case["wall_secs"] <= 0 or case["requests"] <= 0 or case["rounds"] <= 0:
            errors.append(f"{where}: wall_secs/requests/rounds must be positive")
            continue
        rps = case["requests"] / case["wall_secs"]
        if not close(case["requests_per_sec"], rps):
            errors.append(
                f"{where}: requests_per_sec {case['requests_per_sec']} inconsistent "
                f"with requests/wall_secs = {rps:.2f}"
            )
        factor = case["requests"] / case["rounds"]
        if not close(case["coalesce_factor"], factor):
            errors.append(
                f"{where}: coalesce_factor {case['coalesce_factor']} inconsistent "
                f"with requests/rounds = {factor:.3f}"
            )
        if case["rounds"] > case["requests"]:
            errors.append(f"{where}: rounds {case['rounds']} exceeds requests {case['requests']}")
        if case["p50_latency_secs"] > case["p99_latency_secs"]:
            errors.append(f"{where}: p50 exceeds p99")
        # every mode measures real request latencies now (the spawn
        # baseline times each fabric spin-up + transform); zeros on a
        # nonzero-request case mean the writer dropped its samples
        if case["requests"] > 0 and (
            case["p50_latency_secs"] <= 0 or case["p99_latency_secs"] <= 0
        ):
            errors.append(
                f"{where}: zero latency percentiles on a {case['requests']}-request case"
            )
    return errors


def case_key(case):
    return (case["mode"], case["coalesce_window_us"], case["clients"])


def compare(old_path: Path, new_path: Path) -> int:
    errors = []
    docs = {}
    for label, path in (("old", old_path), ("new", new_path)):
        doc, err = load(path)
        if err:
            print(err, file=sys.stderr)
            return 1
        errors += check_doc(doc, f"{label} ({path.name})")
        docs[label] = doc
    if errors:
        for e in errors:
            print(e, file=sys.stderr)
        print(f"{len(errors)} schema problem(s); not comparing", file=sys.stderr)
        return 1

    old_cases = {case_key(c): c for c in docs["old"]["cases"]}
    new_cases = {case_key(c): c for c in docs["new"]["cases"]}
    if set(old_cases) != set(new_cases):
        only_old = sorted(set(old_cases) - set(new_cases))
        only_new = sorted(set(new_cases) - set(old_cases))
        print(
            f"case sweep drifted: only in old {only_old}, only in new {only_new}",
            file=sys.stderr,
        )
        return 1

    # the committed seed marks unmeasured numbers with `provenance`;
    # gating measured runs against a placeholder would be meaningless,
    # so the regression gate only arms against a measured (no
    # provenance) baseline
    gate_armed = "provenance" not in docs["old"]
    if not gate_armed:
        print(
            "old baseline carries `provenance` (unmeasured placeholder seed): "
            "deltas are informational, regression gate disarmed"
        )

    regressions = []
    delta_keys = [
        "wall_secs",
        "requests_per_sec",
        "rounds",
        "coalesce_factor",
        "p50_latency_secs",
        "p99_latency_secs",
    ]
    for key in sorted(old_cases):
        old, new = old_cases[key], new_cases[key]
        mode, window, clients = key
        print(f"{mode} window={window}us clients={clients}:")
        for k in delta_keys:
            ov, nv = old[k], new[k]
            pct = "" if ov == 0 else f" ({(nv - ov) / ov:+.1%})"
            print(f"  {k:>18}: {ov:>10.4g} -> {nv:<10.4g}{pct}")
        if new["requests_per_sec"] < old["requests_per_sec"] * REGRESSION_FLOOR:
            regressions.append(
                f"{mode} window={window}us clients={clients}: requests_per_sec "
                f"{old['requests_per_sec']:.2f} -> {new['requests_per_sec']:.2f} "
                f"(below the {REGRESSION_FLOOR:.0%} floor)"
            )

    if regressions and gate_armed:
        print(f"\n{len(regressions)} throughput regression(s) > 20%:", file=sys.stderr)
        for r in regressions:
            print(f"  {r}", file=sys.stderr)
        return 1
    if regressions:
        print(f"\n{len(regressions)} case(s) below the placeholder numbers (gate disarmed)")
    print(f"\ncompared {len(old_cases)} cases: OK")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--compare",
        nargs=2,
        metavar=("OLD", "NEW"),
        help="compare two bench JSON files and gate on >20%% requests_per_sec regression",
    )
    ns = ap.parse_args()
    if ns.compare:
        return compare(Path(ns.compare[0]), Path(ns.compare[1]))

    path = Path(__file__).resolve().parent.parent / "BENCH_server.json"
    doc, err = load(path)
    if err:
        print(err, file=sys.stderr)
        return 1
    errors = check_doc(doc, path.name)
    for e in errors:
        print(e, file=sys.stderr)
    if errors:
        print(f"{len(errors)} problem(s) in {path}", file=sys.stderr)
        return 1
    print(f"{path.name}: {len(doc.get('cases', []))} cases, schema and invariants OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
