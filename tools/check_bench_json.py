#!/usr/bin/env python3
"""Schema and invariant check for BENCH_server.json.

The `server_throughput` bench overwrites BENCH_server.json at the repo
root on every run; the committed copy is the perf-trajectory seed. This
check keeps the schema STABLE across regenerations so downstream
tooling (perf dashboards, regression diffs) never silently breaks:

* top level carries exactly `bench`, `fixture`, `cases` (plus an
  optional `provenance` string the seed uses to mark unmeasured data);
* the fixture keys and every case's keys match the bench writer
  byte-for-byte — a key added to the writer must be added HERE too;
* the derived columns are self-consistent: `requests_per_sec` agrees
  with `requests / wall_secs`, `coalesce_factor` with
  `requests / rounds`, `rounds <= requests`, and `p50 <= p99`.

Exits nonzero listing every violation.
"""

import json
import sys
from pathlib import Path

FIXTURE_KEYS = {"ranks", "m", "src_block", "dst_block", "scalar"}
CASE_KEYS = {
    "mode",
    "coalesce_window_us",
    "clients",
    "requests",
    "wall_secs",
    "requests_per_sec",
    "rounds",
    "coalesce_factor",
    "p50_latency_secs",
    "p99_latency_secs",
}
MODES = {"spawn-per-transform", "resident"}


def close(a: float, b: float, rel: float = 0.02, absolute: float = 0.02) -> bool:
    return abs(a - b) <= absolute + rel * max(abs(a), abs(b))


def main() -> int:
    path = Path(__file__).resolve().parent.parent / "BENCH_server.json"
    errors = []
    try:
        doc = json.loads(path.read_text(encoding="utf-8"))
    except (OSError, ValueError) as e:
        print(f"{path}: unreadable or invalid JSON: {e}", file=sys.stderr)
        return 1

    top = set(doc)
    if not {"bench", "fixture", "cases"} <= top:
        errors.append(f"top-level keys {sorted(top)} must include bench, fixture, cases")
    if extra := top - {"bench", "fixture", "cases", "provenance"}:
        errors.append(f"unexpected top-level keys {sorted(extra)} — schema drift")
    if doc.get("bench") != "server_throughput":
        errors.append(f"bench is {doc.get('bench')!r}, expected 'server_throughput'")

    fixture = doc.get("fixture", {})
    if set(fixture) != FIXTURE_KEYS:
        errors.append(f"fixture keys {sorted(fixture)} != {sorted(FIXTURE_KEYS)}")

    cases = doc.get("cases", [])
    if not cases:
        errors.append("cases is empty")
    for i, case in enumerate(cases):
        where = f"cases[{i}]"
        if set(case) != CASE_KEYS:
            errors.append(f"{where}: keys {sorted(case)} != {sorted(CASE_KEYS)}")
            continue
        if case["mode"] not in MODES:
            errors.append(f"{where}: mode {case['mode']!r} not in {sorted(MODES)}")
        for key in CASE_KEYS - {"mode"}:
            if not isinstance(case[key], (int, float)) or isinstance(case[key], bool):
                errors.append(f"{where}: {key} is {type(case[key]).__name__}, expected number")
        if any(not isinstance(case[k], (int, float)) for k in CASE_KEYS - {"mode"}):
            continue
        if case["wall_secs"] <= 0 or case["requests"] <= 0 or case["rounds"] <= 0:
            errors.append(f"{where}: wall_secs/requests/rounds must be positive")
            continue
        rps = case["requests"] / case["wall_secs"]
        if not close(case["requests_per_sec"], rps):
            errors.append(
                f"{where}: requests_per_sec {case['requests_per_sec']} inconsistent "
                f"with requests/wall_secs = {rps:.2f}"
            )
        factor = case["requests"] / case["rounds"]
        if not close(case["coalesce_factor"], factor):
            errors.append(
                f"{where}: coalesce_factor {case['coalesce_factor']} inconsistent "
                f"with requests/rounds = {factor:.3f}"
            )
        if case["rounds"] > case["requests"]:
            errors.append(f"{where}: rounds {case['rounds']} exceeds requests {case['requests']}")
        if case["p50_latency_secs"] > case["p99_latency_secs"]:
            errors.append(f"{where}: p50 exceeds p99")

    for e in errors:
        print(e, file=sys.stderr)
    if errors:
        print(f"{len(errors)} problem(s) in {path}", file=sys.stderr)
        return 1
    print(f"{path.name}: {len(cases)} cases, schema and invariants OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
