#!/usr/bin/env python3
"""Tiny markdown link checker for CI.

Scans README.md and docs/*.md for inline markdown links and image
references `[text](target)` and verifies that every relative target
exists in the repository. External links (http/https/mailto) and pure
fragments (#...) are skipped; a `path#fragment` target is checked for
the path part only. Exits nonzero listing every broken link.
"""

import re
import sys
from pathlib import Path

LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")

def targets(md: Path):
    text = md.read_text(encoding="utf-8")
    in_code = False
    for line in text.splitlines():
        if line.strip().startswith("```"):
            in_code = not in_code
            continue
        if in_code:
            continue
        for m in LINK.finditer(line):
            yield m.group(1)

def main() -> int:
    root = Path(__file__).resolve().parent.parent
    files = [root / "README.md"] + sorted((root / "docs").glob("*.md"))
    broken = []
    for md in files:
        if not md.exists():
            broken.append(f"{md}: file listed for checking does not exist")
            continue
        for target in targets(md):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                broken.append(f"{md.relative_to(root)}: broken link -> {target}")
    if broken:
        print("broken documentation links:")
        for b in broken:
            print(f"  {b}")
        return 1
    print(f"doc links OK ({len(files)} files checked)")
    return 0

if __name__ == "__main__":
    sys.exit(main())
