#!/usr/bin/env python3
"""Structural check for Chrome trace-event JSON exported by `costa trace`.

The exporter (`rust/src/obs/export.rs`) hand-rolls its JSON — the crate
is dependency-free — so this checker is what keeps the output honest:
CI exports a trace from a small transform and from a chaos round, then
runs this script over both. It pins exactly the properties a viewer
(chrome://tracing, ui.perfetto.dev) relies on:

* the document parses and carries a `traceEvents` list;
* every event has `ph`, `pid`, `tid`, `name`, and the per-phase
  required keys: `X` (complete) needs numeric `ts` + `dur`, `i`
  (instant) needs numeric `ts` + a scope `s`, `M` (metadata) needs
  `args`;
* within each (pid, tid) track, `X`-event timestamps are
  non-decreasing — the exporter sorts each track snapshot by start
  time, and a violation means the snapshot ordering broke;
* with `--ranks N`: metadata names tracks "rank 0" .. "rank N-1"
  (the per-rank recorder tracks), each carrying at least one event.

Exits nonzero listing every violation.
"""

import argparse
import json
import sys
from collections import defaultdict
from pathlib import Path

KNOWN_PHASES = {"X", "i", "M"}
NUMBER = (int, float)


def check_events(events) -> list:
    errors = []
    # (pid, tid) -> last seen ts of an "X" event
    last_ts = {}
    for i, e in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if ph not in KNOWN_PHASES:
            errors.append(f"{where}: unknown ph {ph!r} (exporter only emits X/i/M)")
            continue
        for key in ("pid", "tid"):
            if not isinstance(e.get(key), int):
                errors.append(f"{where}: {key} missing or not an integer")
        if not isinstance(e.get("name"), str) or not e["name"]:
            errors.append(f"{where}: name missing or empty")
        if ph == "M":
            if not isinstance(e.get("args"), dict):
                errors.append(f"{where}: metadata event without args object")
            continue
        ts = e.get("ts")
        if not isinstance(ts, NUMBER) or isinstance(ts, bool) or ts < 0:
            errors.append(f"{where}: ts missing or not a non-negative number")
            continue
        if e.get("cat") != "costa":
            errors.append(f"{where}: cat is {e.get('cat')!r}, expected 'costa'")
        args = e.get("args")
        if not isinstance(args, dict) or not {"peer", "bytes"} <= set(args):
            errors.append(f"{where}: args must carry peer and bytes")
        if ph == "i":
            if e.get("s") not in {"t", "p", "g"}:
                errors.append(f"{where}: instant event scope s is {e.get('s')!r}")
            continue
        dur = e.get("dur")
        if not isinstance(dur, NUMBER) or isinstance(dur, bool) or dur < 0:
            errors.append(f"{where}: complete event without non-negative dur")
            continue
        track = (e["pid"], e["tid"])
        prev = last_ts.get(track)
        if prev is not None and ts < prev:
            errors.append(
                f"{where}: ts {ts} goes backwards on track pid={track[0]} "
                f"tid={track[1]} (previous span started at {prev})"
            )
        last_ts[track] = ts
    return errors


def check_ranks(events, nranks: int) -> list:
    errors = []
    track_names = {}
    populated = defaultdict(int)
    for e in events:
        if not isinstance(e, dict):
            continue
        if e.get("ph") == "M" and e.get("name") == "thread_name":
            name = e.get("args", {}).get("name")
            if isinstance(name, str):
                track_names[name] = e.get("tid")
        elif e.get("ph") in {"X", "i"}:
            populated[e.get("tid")] += 1
    for r in range(nranks):
        want = f"rank {r}"
        if want not in track_names:
            errors.append(f"no thread_name metadata for track {want!r}")
        elif not populated[track_names[want]]:
            errors.append(f"track {want!r} (tid {track_names[want]}) has no events")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", type=Path, help="trace-event JSON file to check")
    ap.add_argument(
        "--ranks",
        type=int,
        metavar="N",
        help="require populated tracks named 'rank 0' .. 'rank N-1'",
    )
    ns = ap.parse_args()

    try:
        doc = json.loads(ns.trace.read_text(encoding="utf-8"))
    except (OSError, ValueError) as e:
        print(f"{ns.trace}: unreadable or invalid JSON: {e}", file=sys.stderr)
        return 1

    errors = []
    events = doc.get("traceEvents") if isinstance(doc, dict) else None
    if not isinstance(events, list):
        errors.append(f"{ns.trace}: top level must be an object with a traceEvents list")
        events = []
    if isinstance(doc, dict) and doc.get("displayTimeUnit") not in (None, "ms", "ns"):
        errors.append(f"{ns.trace}: displayTimeUnit {doc.get('displayTimeUnit')!r} invalid")
    if not events and not errors:
        errors.append(f"{ns.trace}: traceEvents is empty")

    errors += check_events(events)
    if ns.ranks is not None:
        errors += check_ranks(events, ns.ranks)

    for e in errors:
        print(e, file=sys.stderr)
    if errors:
        print(f"{len(errors)} problem(s) in {ns.trace}", file=sys.stderr)
        return 1
    spans = sum(1 for e in events if isinstance(e, dict) and e.get("ph") == "X")
    instants = sum(1 for e in events if isinstance(e, dict) and e.get("ph") == "i")
    print(f"{ns.trace.name}: {len(events)} events ({spans} spans, {instants} instants) OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
