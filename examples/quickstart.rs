//! Quickstart: the 5-minute tour of COSTA's public API.
//!
//! Builds two different block-cyclic layouts of a 512x512 matrix, then
//! runs `A = 2 * B^T + 0 * A` across 4 simulated ranks — once plainly,
//! once with communication-optimal process relabeling — and prints what
//! moved over the wire.
//!
//! Run: `cargo run --release --example quickstart`

use costa::assignment::Solver;
use costa::engine::{execute_plan, EngineConfig, TransformJob, TransformPlan};
use costa::layout::{block_cyclic, GridOrder, Op};
use costa::metrics::{fmt_bytes, fmt_duration, TransformStats};
use costa::net::Fabric;
use costa::storage::{gather, DistMatrix};

fn main() {
    let ranks = 4;
    // B: 512x512, 32x32 blocks on a 2x2 row-major process grid
    let lb = block_cyclic(512, 512, 32, 32, 2, 2, GridOrder::RowMajor, ranks);
    // A: the transposed target, 128x128 blocks, col-major grid
    let la = block_cyclic(512, 512, 128, 128, 2, 2, GridOrder::ColMajor, ranks);
    let job = TransformJob::<f32>::new(lb, la, Op::Transpose).alpha(2.0).beta(0.0);

    for relabel in [None, Some(Solver::Hungarian)] {
        let mut cfg = EngineConfig::default();
        cfg.relabel = relabel;
        let plan = TransformPlan::build(&job, &cfg);
        let target = plan.target();
        let job2 = job.clone();
        let cfg2 = cfg.clone();
        let plan2 = plan.clone();
        let t = std::time::Instant::now();
        let (results, report) = Fabric::run_report(ranks, None, move |ctx| {
            let b = DistMatrix::generate(ctx.rank(), job2.source(), |i, j| (i * 512 + j) as f32);
            let mut a = DistMatrix::zeros(ctx.rank(), target.clone());
            let stats =
                execute_plan(ctx, &plan2, &job2, &b, &mut a, &cfg2).expect("transform failed");
            (a, stats)
        });
        let wall = t.elapsed();
        let (shards, stats): (Vec<_>, Vec<_>) = results.into_iter().unzip();
        let agg = TransformStats::aggregate(&stats);

        // verify: A[i][j] == 2 * B[j][i]
        let dense = gather(&shards);
        for i in 0..512 {
            for j in 0..512 {
                assert_eq!(dense[i * 512 + j], 2.0 * (j * 512 + i) as f32);
            }
        }

        println!(
            "relabel={:<15} wall={:<9} remote={:<9} messages={:<3} relabeling saved {:.0}% of traffic",
            relabel.map(|s| format!("{s:?}")).unwrap_or_else(|| "off".into()),
            fmt_duration(wall),
            fmt_bytes(report.remote_bytes),
            agg.sent_messages,
            plan.relabeling.reduction_percent(),
        );
    }
    println!("quickstart OK — results verified against the dense oracle");
}
