//! Heterogeneous-network relabeling (paper §3 "Network Topology" +
//! abstract: "COSTA can take advantage of the communication-optimal
//! process relabeling even for heterogeneous network topologies, where
//! latency and bandwidth differ among nodes").
//!
//! A two-level topology (fast intra-node, slow inter-node links) is fed
//! to COPR through the latency–bandwidth cost model. The example runs
//! the same reshuffle three ways — no relabeling, volume-based COPR,
//! topology-aware COPR — under a REAL wire-delay model, and shows the
//! topology-aware relabeling winning on wall-clock, not just on paper.
//!
//! Run: `cargo run --release --example heterogeneous_net`

use costa::assignment::Solver;
use costa::comm::CostModel;
use costa::engine::{execute_plan, EngineConfig, TransformJob, TransformPlan};
use costa::layout::{block_cyclic, GridOrder, Op};
use costa::metrics::{fmt_bytes, fmt_duration, Table};
use costa::net::{Fabric, Topology, WireModel};
use costa::storage::{gather, DistMatrix};

fn main() {
    let ranks = 8;
    let per_node = 4;
    // inter-node links: 40x the latency, 20x the per-byte cost
    let topo = Topology::two_level(ranks, per_node, (5e-6, 2e-9), (2e-4, 4e-8));
    let wire = WireModel {
        topology: topo.clone(),
        time_scale: 1.0,
    };

    // a reshuffle whose natural destination assignment is cross-node:
    // row-major 2x4 grid -> col-major 4x2 grid
    let m = 1024;
    let lb = block_cyclic(m, m, 64, 64, 2, 4, GridOrder::RowMajor, ranks);
    let la = block_cyclic(m, m, 128, 128, 4, 2, GridOrder::ColMajor, ranks);
    let job = TransformJob::<f32>::new(lb, la, Op::Identity);

    let mut table = Table::new(&[
        "relabeling",
        "modeled cost",
        "remote bytes",
        "wall (wire model)",
    ]);
    let cases: Vec<(&str, Option<Solver>, CostModel)> = vec![
        ("off", None, CostModel::LocallyFreeVolume),
        ("volume-based", Some(Solver::Hungarian), CostModel::LocallyFreeVolume),
        (
            "topology-aware",
            Some(Solver::Hungarian),
            CostModel::LatencyBandwidth {
                topology: topo.clone(),
                transform_coeff: 0.0,
            },
        ),
    ];
    let mut walls = Vec::new();
    for (name, relabel, cost) in cases {
        let cfg = EngineConfig {
            relabel,
            cost,
            ..EngineConfig::default()
        };
        let plan = TransformPlan::build(&job, &cfg);
        let target = plan.target();
        let job2 = job.clone();
        let cfg2 = cfg.clone();
        let plan2 = plan.clone();
        let t = std::time::Instant::now();
        let (shards, report) = Fabric::run_report(ranks, Some(wire.clone()), move |ctx| {
            let b = DistMatrix::generate(ctx.rank(), job2.source(), |i, j| (i ^ j) as f32);
            let mut a = DistMatrix::zeros(ctx.rank(), target.clone());
            execute_plan(ctx, &plan2, &job2, &b, &mut a, &cfg2).expect("transform failed");
            a
        });
        let wall = t.elapsed();
        walls.push(wall);
        // correctness under every relabeling
        let dense = gather(&shards);
        for i in 0..m {
            for j in 0..m {
                assert_eq!(dense[i * m + j], (i ^ j) as f32);
            }
        }
        table.row(&[
            name.into(),
            format!("{:.3e}", plan.relabeling.cost_after),
            fmt_bytes(report.remote_bytes),
            fmt_duration(wall),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\ntopology-aware COPR vs no relabeling: {:.2}x faster on the modeled wire",
        walls[0].as_secs_f64() / walls[2].as_secs_f64()
    );
    println!("heterogeneous_net OK — all three variants verified against the oracle");
}
