//! Plan-compilation cache: repeated redistributions through the
//! [`TransformService`].
//!
//! The CP2K/RPA workload (paper §7.3) re-runs the SAME reshuffle once per
//! multiplication, thousands of times per simulation. Planning it —
//! building the volume matrix, solving the relabeling LAP (Alg. 1),
//! constructing the package matrix (Alg. 2) — is pure in the layouts, so
//! it should be paid once. This example runs 10 identical transforms
//! through a shared service and prints the cache's own accounting:
//! after iteration 1, zero LAP solves, zero package construction,
//! planning time amortized toward zero.
//!
//! Run: `cargo run --release --example plan_cache`

use std::sync::Arc;

use costa::assignment::Solver;
use costa::engine::{EngineConfig, TransformJob};
use costa::layout::{block_cyclic, GridOrder, Op};
use costa::metrics::fmt_duration;
use costa::net::Fabric;
use costa::service::TransformService;
use costa::storage::{gather, DistMatrix};

fn main() {
    let ranks = 4;
    let iterations = 10;
    let lb = block_cyclic(768, 768, 32, 32, 2, 2, GridOrder::RowMajor, ranks);
    let la = block_cyclic(768, 768, 128, 128, 2, 2, GridOrder::ColMajor, ranks);
    let job = TransformJob::<f32>::new(lb, la, Op::Transpose).alpha(1.0);

    let svc = Arc::new(TransformService::new(
        EngineConfig::default().with_relabel(Solver::Hungarian),
    ));

    let mut baseline = svc.report();
    for iter in 0..iterations {
        let svc2 = svc.clone();
        let job2 = job.clone();
        let target = svc.target_for(&job);
        let shards = Fabric::run(ranks, None, move |ctx| {
            let b = DistMatrix::generate(ctx.rank(), job2.source(), |i, j| (i * 768 + j) as f32);
            let mut a = DistMatrix::zeros(ctx.rank(), target.clone());
            svc2.transform(ctx, &job2, &b, &mut a).expect("transform failed");
            a
        });
        // verify every iteration against the oracle: A[i][j] = B[j][i]
        let dense = gather(&shards);
        for i in 0..768 {
            for j in 0..768 {
                assert_eq!(dense[i * 768 + j], (j * 768 + i) as f32);
            }
        }
        let now = svc.report();
        let d = now.since(&baseline);
        println!(
            "iter {iter:>2}: plan requests {:>2} (hits {:>2}, misses {}), LAP solves {}, package builds {}, planning {}",
            d.requests(),
            d.hits,
            d.misses,
            d.lap_solves,
            d.package_builds,
            fmt_duration(d.planning_time),
        );
        baseline = now;
    }

    let total = svc.report();
    println!(
        "\ntotal: {} requests, hit rate {:.1}%, planning paid ONCE: {} total, {} amortized per request",
        total.requests(),
        100.0 * total.hit_rate(),
        fmt_duration(total.planning_time),
        fmt_duration(total.amortized_planning_time()),
    );
    assert_eq!(total.misses, 1, "exactly one plan build across {iterations} iterations");
    assert_eq!(total.lap_solves, 1);
    assert_eq!(total.package_builds, 1);
    println!("plan_cache OK — iterations 2..{iterations} performed zero planning work");
}
