//! END-TO-END DRIVER (DESIGN.md FIG4/FIG5): the full CP2K-RPA
//! integration on a real (scaled) workload, exercising every layer:
//!
//!   L1 Pallas kernels  —→ AOT HLO artifacts —→ L3 PJRT runtime
//!   COSTA engine (batched reshuffle + transpose + relabeling)
//!   COSMA-substrate distributed GEMM over the simulated fabric
//!   ScaLAPACK baseline (pdtran + eager pdgemm) as the comparator
//!
//! It runs several RPA iterations of `C = A^T B` (A, B = paper shape
//! 3,473,408 x 17,408 scaled by 1/1024), cross-checks the two flows'
//! results numerically, and prints the Fig. 4-style table: total MM
//! time per flow, COSTA's share of the COSMA flow (paper claims ≈10%),
//! and the relabeling traffic reduction (Fig. 6's quantity).
//!
//! Run: `make artifacts && cargo run --release --example rpa_integration`

use std::sync::Arc;

use costa::assignment::Solver;
use costa::cosma::{cosma_gemm_tn, GemmConfig};
use costa::engine::{execute_batch, BatchPlan, EngineConfig, KernelBackend, TransformJob};
use costa::layout::Op;
use costa::metrics::{fmt_duration, Table};
use costa::net::Fabric;
use costa::rpa::{run_cosma_costa, run_scalapack, RpaStats, RpaWorkload};
use costa::runtime::Runtime;
use costa::scalapack::{pdgemm_tn, pdtran};
use costa::storage::{gather, DistMatrix};

fn main() {
    let ranks = 16;
    let scale = 256;
    let iters = 2;
    let w = RpaWorkload::paper_scaled(scale, ranks, iters).with_block(32);
    println!("== RPA end-to-end (paper Figs. 4/5/6, scaled 1/{scale}) ==");
    println!("{}\n", w.describe());

    // PJRT runtime: local GEMM tiles go through the AOT Pallas artifact
    let backend = match Runtime::load_default() {
        Ok(rt) => {
            println!("PJRT runtime loaded ({} artifacts)", rt.artifact_names().len());
            KernelBackend::Pjrt(Arc::new(rt))
        }
        Err(e) => {
            println!("PJRT unavailable ({e:#}); native kernels only");
            KernelBackend::Native
        }
    };

    // --- numerical cross-check first (one iteration, both flows) -------
    cross_check(&w);

    // --- Fig. 4: MM time per flow --------------------------------------
    let mut table = Table::new(&[
        "flow",
        "MM time",
        "reshuffle",
        "gemm",
        "reshuffle %",
        "GFLOP",
    ]);

    let cfg = EngineConfig {
        relabel: Some(Solver::Greedy), // the paper's production solver
        backend: backend.clone(),
        ..EngineConfig::default()
    };
    let w2 = w.clone();
    let cfg2 = cfg.clone();
    let cosma_stats: Vec<RpaStats> =
        Fabric::run(ranks, None, move |ctx| run_cosma_costa(ctx, &w2, &cfg2));
    let cosma = RpaStats::aggregate(&cosma_stats);
    table.row(&[
        "cosma+costa".into(),
        fmt_duration(cosma.mm_time),
        fmt_duration(cosma.reshuffle_time),
        fmt_duration(cosma.gemm_time),
        format!("{:.1}", 100.0 * cosma.reshuffle_share()),
        format!("{:.2}", cosma.flops as f64 / 1e9),
    ]);

    let w3 = w.clone();
    let scal_stats: Vec<RpaStats> = Fabric::run(ranks, None, move |ctx| run_scalapack(ctx, &w3));
    let scal = RpaStats::aggregate(&scal_stats);
    table.row(&[
        "scalapack".into(),
        fmt_duration(scal.mm_time),
        fmt_duration(scal.reshuffle_time),
        fmt_duration(scal.gemm_time),
        format!("{:.1}", 100.0 * scal.reshuffle_share()),
        format!("{:.2}", scal.flops as f64 / 1e9),
    ]);
    print!("{}", table.render());

    let speedup = scal.mm_time.as_secs_f64() / cosma.mm_time.as_secs_f64();
    println!("\ncosma+costa vs scalapack speedup: {speedup:.2}x (paper: COSMA+COSTA wins at every node count)");

    // --- Fig. 6: relabeling volume reduction for these exact layouts ----
    let job_a = TransformJob::<f32>::new(
        (*w.scalapack_a_t()).clone(),
        (*w.cosma_a()).clone(),
        Op::Transpose,
    );
    let job_b = TransformJob::<f32>::new(
        (*w.scalapack_b()).clone(),
        (*w.cosma_b()).clone(),
        Op::Identity,
    );
    let plan = BatchPlan::build(
        &[job_a, job_b],
        &EngineConfig::default().with_relabel(Solver::Hungarian),
    );
    println!(
        "relabeling reduces the A+B reshuffle volume by {:.1}% at {ranks} ranks (Fig. 6 quantity)",
        plan.relabeling.reduction_percent()
    );
    assert!(speedup > 1.0, "COSMA+COSTA must beat the eager baseline");
    println!("\nrpa_integration OK");
}

/// One iteration of both flows on a tiny instance; the gathered C
/// matrices must agree to f32 reduction tolerance.
fn cross_check(w: &RpaWorkload) {
    let mut w = w.clone();
    w.iterations = 1;
    let ranks = w.nprocs;
    let w_a = w.clone();
    let cosma_c = Fabric::run(ranks, None, move |ctx| {
        let me = ctx.rank();
        let a_t = DistMatrix::generate(me, w_a.scalapack_a_t(), costa::rpa::value_a);
        let b = DistMatrix::generate(me, w_a.scalapack_b(), costa::rpa::value_b);
        let cfg = EngineConfig::default();
        let job_a = TransformJob::<f32>::new(
            (*w_a.scalapack_a_t()).clone(),
            (*w_a.cosma_a()).clone(),
            Op::Transpose,
        );
        let job_b = TransformJob::<f32>::new(
            (*w_a.scalapack_b()).clone(),
            (*w_a.cosma_b()).clone(),
            Op::Identity,
        );
        let jobs = [job_a, job_b];
        let plan = BatchPlan::build(&jobs, &cfg);
        let mut a_c = DistMatrix::<f32>::zeros(me, plan.targets[0].clone());
        let mut b_c = DistMatrix::<f32>::zeros(me, plan.targets[1].clone());
        {
            let bs = [&a_t, &b];
            let mut as_: [&mut DistMatrix<f32>; 2] = [&mut a_c, &mut b_c];
            execute_batch(ctx, &plan, &jobs, &bs, &mut as_, &cfg).expect("reshuffle failed");
        }
        let mut c = DistMatrix::<f32>::zeros(me, w_a.scalapack_c());
        cosma_gemm_tn(ctx, 1.0, 0.0, &a_c, &b_c, &mut c, &GemmConfig::default())
            .expect("COSMA GEMM failed");
        c
    });
    let w_b = w.clone();
    let scal_c = Fabric::run(ranks, None, move |ctx| {
        let me = ctx.rank();
        let a_t = DistMatrix::generate(me, w_b.scalapack_a_t(), costa::rpa::value_a);
        let b = DistMatrix::generate(me, w_b.scalapack_b(), costa::rpa::value_b);
        let mut a_sc = DistMatrix::<f32>::zeros(me, w_b.scalapack_a());
        pdtran(ctx, 1.0, 0.0, &a_t, &mut a_sc).expect("baseline transpose failed");
        let mut c = DistMatrix::<f32>::zeros(me, w_b.scalapack_c());
        pdgemm_tn(ctx, 1.0, 0.0, &a_sc, &b, &mut c, &KernelBackend::Native)
            .expect("baseline pdgemm failed");
        c
    });
    let gc = gather(&cosma_c);
    let gs = gather(&scal_c);
    let mut max_rel = 0.0f64;
    for (x, y) in gc.iter().zip(&gs) {
        let rel = ((x - y).abs() / (1.0 + y.abs())) as f64;
        max_rel = max_rel.max(rel);
    }
    assert!(max_rel < 1e-2, "flows disagree: max rel err {max_rel}");
    println!("cross-check: cosma+costa and scalapack flows agree (max rel err {max_rel:.2e})\n");
}
