//! Block-size tuning (the paper's Fig. 3 scenario as an example):
//! a 100,000 x 100,000 matrix on a 10x10 process grid must move from an
//! application's block size to the machine's optimal block size (10^4).
//! How much of that traffic can process relabeling eliminate?
//!
//! Volumes are computed analytically (the factorised block-cyclic path),
//! so this runs the FULL paper-scale instance in milliseconds per point.
//!
//! Run: `cargo run --release --example block_size_tuning`

use costa::assignment::Solver;
use costa::bench::{fig3_blocks, fig3_point};
use costa::metrics::{fmt_bytes, Table};

fn main() {
    let size = 100_000;
    let grid = 10;
    let target_block = 10_000;
    println!(
        "Fig. 3 scenario: {size}x{size} f64 matrix, {grid}x{grid} grids \
         (row-major initial, col-major target), target block {target_block}"
    );

    let mut table = Table::new(&[
        "initial block",
        "remote traffic (no relabel)",
        "remote traffic (COPR)",
        "reduction %",
    ]);
    let mut full_recovery_at_target = false;
    for block in fig3_blocks(size, target_block, 16) {
        let (before, after) = fig3_point(size, grid, block, target_block, Solver::Hungarian);
        let red = if before == 0 {
            100.0
        } else {
            100.0 * (before - after) as f64 / before as f64
        };
        if block == target_block && after == 0 {
            full_recovery_at_target = true;
        }
        table.row(&[
            block.to_string(),
            fmt_bytes(8 * before),
            fmt_bytes(8 * after),
            format!("{red:.2}"),
        ]);
    }
    print!("{}", table.render());
    assert!(
        full_recovery_at_target,
        "at equal block sizes relabeling must eliminate ALL communication (the red dot)"
    );
    println!(
        "\nred dot reproduced: equal blocks (10^4) -> 100% of the remote \
         traffic eliminated by relabeling"
    );
}
