# AOT lowering: jax -> HLO TEXT artifacts for the Rust PJRT runtime.
#
# HLO *text* (not serialized HloModuleProto) is the interchange format:
# jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
# xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
# reassigns ids, so text round-trips cleanly. See /opt/xla-example.
#
# Run via `make artifacts` (no-op when inputs are unchanged). Emits one
# artifacts/<name>.hlo.txt per variant in model.graphs() plus
# artifacts/manifest.json describing parameter shapes for the Rust side.
import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import graphs


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True; the
    Rust side unwraps with to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def example_args(meta):
    """ShapeDtypeStructs for a variant's parameters, in call order."""
    s = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.float32)
    scalar = s(1)
    if meta["kind"] == "transform":
        m, n = meta["m"], meta["n"]
        b = s(m, n) if meta["op"] == "N" else s(n, m)
        return (scalar, scalar, s(m, n), b)
    if meta["kind"] == "gemm_tn":
        m, n, k = meta["m"], meta["n"], meta["k"]
        return (scalar, scalar, s(m, n), s(k, m), s(k, n))
    raise ValueError(f"unknown kind {meta['kind']!r}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    # kept for Makefile compatibility: --out names the stamp file
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {}
    for name, (fn, meta) in sorted(graphs().items()):
        ex = example_args(meta)
        text = to_hlo_text(jax.jit(fn).lower(*ex))
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            **meta,
            "file": f"{name}.hlo.txt",
            "params": [list(a.shape) for a in ex],
            "dtype": "f32",
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    # TSV twin of the manifest for the Rust runtime (offline env has no
    # serde_json): name \t kind \t op \t m \t n \t k \t file \t params
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        for name, e in sorted(manifest.items()):
            params = ";".join(",".join(map(str, p)) for p in e["params"])
            f.write(
                "\t".join(
                    [
                        name,
                        e["kind"],
                        e.get("op", "-"),
                        str(e["m"]),
                        str(e["n"]),
                        str(e.get("k", 0)),
                        e["file"],
                        params,
                    ]
                )
                + "\n"
            )
    if args.out is not None:
        # stamp file so the Makefile dependency tracking has one target
        with open(args.out, "w") as f:
            f.write("\n".join(sorted(manifest)) + "\n")
    print(f"wrote manifest with {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
