# L2: the jax compute graphs COSTA's Rust engine executes locally.
#
# Two graph families, both built on the L1 Pallas kernels:
#   transform_graph(op, block) -> f(alpha, beta, a, b)    [Eq. 14 per package]
#   gemm_graph(block)          -> f(alpha, beta, c, a, b) [COSMA local GEMM]
#
# These are lowered ONCE by aot.py to HLO text artifacts; the Rust runtime
# (rust/src/runtime/) loads and executes them on the PJRT CPU client from
# the request path. Python never runs at request time.
#
# L2 performance notes (DESIGN.md §Perf):
#  * each graph is a single pallas_call — there is nothing for XLA to
#    fuse across, and no recomputation by construction;
#  * alpha/beta enter as shape-(1,) parameters (not python floats) so one
#    compiled executable serves every scalar pair — the Rust side would
#    otherwise need one artifact per (alpha, beta);
#  * HLO text interchange carries no donation metadata, so the graphs are
#    kept pure and the Rust engine recycles its own buffers instead.
import functools

from .kernels import gemm_tn, transform

# Artifact shape variants. The Rust engine picks the largest transform
# artifact that tiles a package and falls back to its native kernel for
# remainders; bigger variants amortise PJRT dispatch over more elements.
TRANSFORM_SIZES = (64, 128, 256, 512)
GEMM_SIZES = (128, 256)


def transform_graph(op, block=(128, 128)):
    """Return f(alpha, beta, a, b) = alpha*op(b) + beta*a, tiled."""

    def f(alpha, beta, a, b):
        return (transform(alpha, beta, a, b, op=op, block=block),)

    f.__name__ = f"transform_{op.lower()}_{block[0]}x{block[1]}"
    return f


def gemm_graph(block=(128, 128, 128)):
    """Return f(alpha, beta, c, a, b) = alpha*a^T b + beta*c, tiled."""

    def f(alpha, beta, c, a, b):
        return (gemm_tn(alpha, beta, c, a, b, block=block),)

    f.__name__ = f"gemm_tn_{block[0]}x{block[1]}x{block[2]}"
    return f


@functools.lru_cache(maxsize=None)
def graphs():
    """All graph variants aot.py emits: name -> (fn, meta).

    Kept in one place so aot.py, the pytests and the Rust artifact
    registry (runtime/mod.rs) agree on the variant set. meta mirrors
    what aot.py writes into artifacts/manifest.json.
    """
    out = {}
    for op in ("N", "T"):
        for size in TRANSFORM_SIZES:
            blk = min(size, 128)
            out[f"transform_{op.lower()}_{size}x{size}"] = (
                transform_graph(op, block=(blk, blk)),
                {"kind": "transform", "op": op, "m": size, "n": size},
            )
    for size in GEMM_SIZES:
        out[f"gemm_tn_{size}"] = (
            gemm_graph(block=(128, 128, 128)),
            {"kind": "gemm_tn", "m": size, "n": size, "k": size},
        )
    return out
