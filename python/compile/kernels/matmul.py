# L1 Pallas kernel: MXU-targeted tiled GEMM-accumulate, C <- alpha*A^T B + beta*C.
#
# This is the local compute of the COSMA-substrate distributed GEMM
# (rust/src/cosma/gemm.rs): each rank multiplies its (k, m) panel of A by
# its (k, n) panel of B and accumulates into a (m, n) tile of C. The
# transposed-first-operand form is exactly the RPA-dominant multiplication
# (paper Fig. 5: C = A^T B with A, B tall-and-skinny).
#
# TPU mapping (DESIGN.md §Hardware-Adaptation): (bm, bn, bk) = (128, 128,
# 128) matches the 128x128 MXU systolic array; the jnp.dot below contracts
# over the leading axis of both VMEM tiles (dot_general, no materialised
# transpose) and accumulates in f32 via preferred_element_type. The k-axis
# is the innermost grid dimension, so the output tile stays resident in
# VMEM across the whole reduction (revisiting pattern).
#
# VMEM per step: bk*bm + bk*bn + 2*bm*bn floats = 256 KiB at 128^3 f32.
# Arithmetic intensity at 128^3: 2*128^3 flops / (3*128^2*4 B) ~ 85
# flops/byte — comfortably MXU-bound, not HBM-bound.
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_tn_kernel(alpha_ref, beta_ref, c_ref, a_ref, b_ref, o_ref):
    """Output tile (i, j); reduction step k = program_id(2)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = beta_ref[0] * c_ref[...]

    a = a_ref[...]  # (bk, bm) panel of A
    b = b_ref[...]  # (bk, bn) panel of B
    # contract over axis 0 of both: A^T B without materialising A.T
    acc = jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] += alpha_ref[0] * acc


def gemm_tn(alpha, beta, c, a, b, *, block=(128, 128, 128)):
    """C <- alpha * A^T B + beta * C, tiled.

    a: (k, m); b: (k, n); c: (m, n). alpha, beta: shape-(1,) arrays.
    k, m, n must be divisible by the block shape.
    """
    kk, m = a.shape
    _, n = b.shape
    bm, bn, bk = block
    if m % bm or n % bn or kk % bk:
        raise ValueError(f"shapes {(kk, m, n)} not divisible by block {block}")
    grid = (m // bm, n // bn, kk // bk)
    scalar_spec = pl.BlockSpec((1,), lambda i, j, k: (0,))
    return pl.pallas_call(
        _gemm_tn_kernel,
        grid=grid,
        in_specs=[
            scalar_spec,
            scalar_spec,
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),  # C
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)),  # A panel
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),  # B panel
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(alpha, beta, c, a, b)
