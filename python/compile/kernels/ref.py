# Pure-jnp correctness oracles for the Pallas kernels.
#
# These define the semantics that both the L1 Pallas kernels (kernels/
# transform.py, kernels/matmul.py) and the Rust fallback kernels
# (rust/src/engine/transform_kernel.rs) must match bit-for-bit (f32,
# modulo usual float addition reassociation in the GEMM reduction).
import jax.numpy as jnp

OPS = ("N", "T", "C")


def apply_op(b, op):
    """op(B) with op in {identity, transpose, conjugate-transpose}."""
    if op == "N":
        return b
    if op == "T":
        return b.T
    if op == "C":
        return jnp.conj(b).T
    raise ValueError(f"unknown op {op!r}")


def transform_ref(alpha, beta, a, b, op):
    """A <- alpha * op(B) + beta * A   (Eq. 14 of the paper, per tile)."""
    return alpha * apply_op(b, op) + beta * a


def gemm_tn_ref(alpha, beta, c, a, b):
    """C <- alpha * A^T B + beta * C  (the RPA-dominant multiplication,
    Fig. 5: A, B are tall-and-skinny, C = A^T B)."""
    return alpha * (a.T @ b) + beta * c
