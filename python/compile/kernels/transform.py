# L1 Pallas kernel: tiled scale/transpose/axpby transform.
#
#   A <- alpha * op(B) + beta * A,  op in {N (identity), T, C (conj-T)}
#
# This is the paper's "cache-friendly, multi-threaded kernel for matrix
# transposition" (COSTA §6), rethought for TPU per DESIGN.md
# §Hardware-Adaptation:
#
#   * the CPU cache-blocking becomes BlockSpec-driven (bm, bn) tiling into
#     VMEM: the index maps below ARE the HBM<->VMEM schedule the paper
#     expressed with OpenMP loop blocking;
#   * op(B) is applied on the VMEM-resident tile (a lane shuffle on real
#     TPU), and alpha/beta are fused into the same pass so every tile is
#     read from HBM exactly once and written exactly once — the transform
#     is purely memory-bound, so single-pass is roofline-optimal;
#   * for op in {T, C} the B tile for output tile (i, j) is B[j, i] of
#     shape (bn, bm): both input and output streams stay contiguous in HBM.
#
# VMEM footprint per grid step: (2*bm*bn + bn*bm) * 4 B for f32
# (A in, B in, O out) = 3 * bm * bn * 4 B -> 192 KiB at 128x128, leaving
# ~80x headroom in a 16 MiB VMEM for double-buffering the pipeline.
#
# interpret=True ALWAYS: the CPU PJRT plugin cannot run Mosaic
# custom-calls; correctness is validated on the interpret path and real-TPU
# performance is estimated from the VMEM/MXU analysis in DESIGN.md §Perf.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import OPS


def _transform_kernel(alpha_ref, beta_ref, a_ref, b_ref, o_ref, *, op):
    """One (bm, bn) output tile. b_ref is (bn, bm) for op in {T, C}."""
    alpha = alpha_ref[0]
    beta = beta_ref[0]
    b = b_ref[...]
    if op == "T":
        b = b.T
    elif op == "C":
        b = jnp.conj(b).T
    o_ref[...] = alpha * b + beta * a_ref[...]


def transform(alpha, beta, a, b, *, op="N", block=(128, 128)):
    """Tiled A <- alpha*op(B) + beta*A via pallas_call.

    a: (m, n); b: (m, n) for op == "N", (n, m) for op in {"T", "C"}.
    alpha, beta: shape-(1,) arrays (kept rank-1 so they stay real kernel
    operands rather than being constant-folded at trace time).
    m, n must be divisible by the block shape; callers (aot.py and the
    Rust engine) pad or fall back for remainders.
    """
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}")
    m, n = a.shape
    bm, bn = block
    if m % bm or n % bn:
        raise ValueError(f"shape {(m, n)} not divisible by block {block}")
    grid = (m // bm, n // bn)
    scalar_spec = pl.BlockSpec((1,), lambda i, j: (0,))
    a_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    if op == "N":
        b_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    else:
        # transposed read: output tile (i, j) consumes input tile (j, i)
        b_spec = pl.BlockSpec((bn, bm), lambda i, j: (j, i))
    return pl.pallas_call(
        functools.partial(_transform_kernel, op=op),
        grid=grid,
        in_specs=[scalar_spec, scalar_spec, a_spec, b_spec],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(alpha, beta, a, b)
