# L1: Pallas kernels for COSTA's compute hot-spots.
#  - transform: A <- alpha*op(B) + beta*A   (the shuffle-and-transpose tile op)
#  - gemm_tn:   C <- alpha*A^T B + beta*C   (COSMA-substrate local GEMM)
# ref.py holds the pure-jnp oracles both are tested against.
from .matmul import gemm_tn
from .ref import OPS, apply_op, gemm_tn_ref, transform_ref
from .transform import transform

__all__ = [
    "OPS",
    "apply_op",
    "gemm_tn",
    "gemm_tn_ref",
    "transform",
    "transform_ref",
]
