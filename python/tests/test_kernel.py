# pytest: Pallas kernels vs pure-jnp oracle — the CORE correctness signal.
#
# hypothesis sweeps shapes (block-aligned and remainder-triggering),
# dtypes, scalars and ops; every property asserts allclose against ref.py.
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import (
    OPS,
    apply_op,
    gemm_tn,
    gemm_tn_ref,
    transform,
    transform_ref,
)

settings.register_profile("kernels", deadline=None, max_examples=25)
settings.load_profile("kernels")


def rng(seed):
    return np.random.default_rng(seed)


def mk(shape, dtype, seed=0):
    r = rng(seed)
    if np.issubdtype(dtype, np.complexfloating):
        return (r.standard_normal(shape) + 1j * r.standard_normal(shape)).astype(
            dtype
        )
    return r.standard_normal(shape).astype(dtype)


def scal(x):
    return jnp.array([x], dtype=jnp.float32)


# ---------------------------------------------------------------- transform


@pytest.mark.parametrize("op", ["N", "T"])
@pytest.mark.parametrize("block", [(8, 8), (16, 32)])
def test_transform_matches_ref_basic(op, block):
    m, n = 32, 64
    a = mk((m, n), np.float32, 1)
    b = mk((m, n) if op == "N" else (n, m), np.float32, 2)
    got = transform(scal(1.5), scal(-0.5), a, b, op=op, block=block)
    want = transform_ref(1.5, -0.5, a, b, op)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@given(
    op=st.sampled_from(["N", "T"]),
    ti=st.integers(1, 6),
    tj=st.integers(1, 6),
    bi=st.sampled_from([4, 8, 16]),
    bj=st.sampled_from([4, 8, 16]),
    alpha=st.floats(-3, 3, allow_nan=False, width=32),
    beta=st.floats(-3, 3, allow_nan=False, width=32),
    seed=st.integers(0, 2**16),
)
def test_transform_matches_ref_swept(op, ti, tj, bi, bj, alpha, beta, seed):
    m, n = ti * bi, tj * bj
    a = mk((m, n), np.float32, seed)
    b = mk((m, n) if op == "N" else (n, m), np.float32, seed + 1)
    got = transform(scal(alpha), scal(beta), a, b, op=op, block=(bi, bj))
    want = transform_ref(np.float32(alpha), np.float32(beta), a, b, op)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_transform_identity_alpha1_beta0_is_op():
    m, n = 16, 24
    b = mk((n, m), np.float32, 7)
    a = np.zeros((m, n), np.float32)
    got = transform(scal(1.0), scal(0.0), a, b, op="T", block=(8, 8))
    np.testing.assert_array_equal(np.asarray(got), b.T)


def test_transform_beta_only_keeps_a():
    m, n = 8, 8
    a = mk((m, n), np.float32, 3)
    b = mk((m, n), np.float32, 4)
    got = transform(scal(0.0), scal(2.0), a, b, op="N", block=(8, 8))
    np.testing.assert_allclose(got, 2.0 * a, rtol=1e-6)


def test_transform_rejects_bad_shape():
    a = np.zeros((10, 10), np.float32)
    with pytest.raises(ValueError, match="not divisible"):
        transform(scal(1.0), scal(0.0), a, a, op="N", block=(8, 8))


def test_transform_rejects_bad_op():
    a = np.zeros((8, 8), np.float32)
    with pytest.raises(ValueError, match="unknown op"):
        transform(scal(1.0), scal(0.0), a, a, op="X", block=(8, 8))


def test_conjugate_transpose_ref_semantics():
    # op == "C" lives in ref + the Rust engine (complex); here we pin the
    # oracle's semantics so the Rust tests and ref.py agree.
    b = mk((4, 6), np.complex64, 11)
    got = np.asarray(apply_op(b, "C"))
    np.testing.assert_array_equal(got, b.conj().T)
    assert set(OPS) == {"N", "T", "C"}


# ----------------------------------------------------------------- gemm_tn


@pytest.mark.parametrize("shape", [(16, 8, 8), (32, 16, 24)])
def test_gemm_tn_matches_ref_basic(shape):
    k, m, n = shape
    a = mk((k, m), np.float32, 1)
    b = mk((k, n), np.float32, 2)
    c = mk((m, n), np.float32, 3)
    got = gemm_tn(scal(1.0), scal(1.0), c, a, b, block=(8, 8, 8))
    want = gemm_tn_ref(np.float32(1.0), np.float32(1.0), c, a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(
    tk=st.integers(1, 4),
    tm=st.integers(1, 3),
    tn=st.integers(1, 3),
    alpha=st.floats(-2, 2, allow_nan=False, width=32),
    beta=st.floats(-2, 2, allow_nan=False, width=32),
    seed=st.integers(0, 2**16),
)
def test_gemm_tn_matches_ref_swept(tk, tm, tn, alpha, beta, seed):
    bk, bm, bn = 8, 8, 8
    k, m, n = tk * bk, tm * bm, tn * bn
    a = mk((k, m), np.float32, seed)
    b = mk((k, n), np.float32, seed + 1)
    c = mk((m, n), np.float32, seed + 2)
    got = gemm_tn(scal(alpha), scal(beta), c, a, b, block=(bm, bn, bk))
    want = gemm_tn_ref(np.float32(alpha), np.float32(beta), c, a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gemm_tn_beta_zero_overwrites_c_nan_free():
    # beta=0 must overwrite C even when C holds garbage (paper's pxtran
    # beta=0 semantics): init step writes beta*C, so C must still be
    # finite; NaN*0 propagation is the documented exclusion.
    k, m, n = 8, 8, 8
    a = mk((k, m), np.float32, 1)
    b = mk((k, n), np.float32, 2)
    c = np.full((m, n), 1e30, np.float32)
    got = gemm_tn(scal(1.0), scal(0.0), c, a, b, block=(8, 8, 8))
    np.testing.assert_allclose(
        got, gemm_tn_ref(np.float32(1.0), np.float32(0.0), c, a, b), rtol=1e-4
    )


def test_gemm_tn_rejects_bad_shape():
    a = np.zeros((12, 8), np.float32)
    b = np.zeros((12, 8), np.float32)
    c = np.zeros((8, 8), np.float32)
    with pytest.raises(ValueError, match="not divisible"):
        gemm_tn(scal(1.0), scal(0.0), c, a, b, block=(8, 8, 8))
