# pytest: L2 graph variants lower to HLO text and keep ref semantics.
import json
import os
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot
from compile.kernels import gemm_tn_ref, transform_ref
from compile.model import GEMM_SIZES, TRANSFORM_SIZES, graphs


def test_variant_set_is_complete():
    g = graphs()
    for op in ("n", "t"):
        for s in TRANSFORM_SIZES:
            assert f"transform_{op}_{s}x{s}" in g
    for s in GEMM_SIZES:
        assert f"gemm_tn_{s}" in g
    assert len(g) == 2 * len(TRANSFORM_SIZES) + len(GEMM_SIZES)


@pytest.mark.parametrize("name", sorted(graphs()))
def test_example_args_match_graph(name):
    fn, meta = graphs()[name]
    ex = aot.example_args(meta)
    out = jax.eval_shape(fn, *ex)
    assert out[0].shape == (meta["m"], meta["n"])
    assert out[0].dtype == jnp.float32


@pytest.mark.parametrize("name", ["transform_t_128x128", "transform_n_64x64"])
def test_transform_graph_matches_ref(name):
    fn, meta = graphs()[name]
    m, n = meta["m"], meta["n"]
    r = np.random.default_rng(0)
    a = r.standard_normal((m, n)).astype(np.float32)
    bshape = (m, n) if meta["op"] == "N" else (n, m)
    b = r.standard_normal(bshape).astype(np.float32)
    alpha, beta = np.float32(2.0), np.float32(-1.0)
    (got,) = fn(jnp.array([alpha]), jnp.array([beta]), a, b)
    want = transform_ref(alpha, beta, a, b, meta["op"])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_gemm_graph_matches_ref():
    fn, meta = graphs()["gemm_tn_128"]
    m, n, k = meta["m"], meta["n"], meta["k"]
    r = np.random.default_rng(1)
    a = r.standard_normal((k, m)).astype(np.float32)
    b = r.standard_normal((k, n)).astype(np.float32)
    c = r.standard_normal((m, n)).astype(np.float32)
    (got,) = fn(jnp.array([1.0], jnp.float32), jnp.array([0.5], jnp.float32), c, a, b)
    want = gemm_tn_ref(np.float32(1.0), np.float32(0.5), c, a, b)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_hlo_text_lowering_roundtrip():
    # Smallest transform variant: lower to HLO text, check it parses as
    # an ENTRY module with the right parameter count (what the Rust
    # HloModuleProto::from_text_file parser consumes).
    fn, meta = graphs()["transform_n_64x64"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*aot.example_args(meta)))
    assert "ENTRY" in text
    # entry layout lists exactly the 4 params: alpha, beta, a, b
    assert (
        "entry_computation_layout={(f32[1]{0}, f32[1]{0}, "
        "f32[64,64]{1,0}, f32[64,64]{1,0})" in text
    )


def test_aot_main_writes_manifest(monkeypatch):
    with tempfile.TemporaryDirectory() as d:
        monkeypatch.setattr(
            "sys.argv", ["aot", "--out-dir", d, "--out", os.path.join(d, ".stamp")]
        )
        aot.main()
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        assert set(manifest) == set(graphs())
        for name, entry in manifest.items():
            assert os.path.exists(os.path.join(d, entry["file"]))
            assert entry["dtype"] == "f32"
            assert all(isinstance(p, list) for p in entry["params"])
        assert os.path.exists(os.path.join(d, ".stamp"))
